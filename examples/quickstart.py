"""Quickstart: the paper's approximate autotuning, end to end, through the
session API (`repro.api`).

Autotunes Capital's recursive 3D Cholesky (15 configurations: block size x
base-case strategy) on the virtual 64-rank machine, comparing full
execution against the paper's five selective-execution policies at one
confidence tolerance.  The policy sweep runs process-parallel (one forked
worker per policy) and produces the same merged results as a serial run.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import time

from repro.api import AutotuneSession, SimBackend
from repro.core.policies import POLICIES
from repro.linalg.studies import search_space


def main():
    tol = 0.25
    # floor of 2: a single-core box should still demonstrate (and
    # exercise) the fork-parallel sweep path rather than degenerate serial
    workers = max(2, min(len(POLICIES), os.cpu_count() or 1))
    print(f"autotuning Capital Cholesky (15 configs, 64 virtual ranks), "
          f"tolerance {tol}, {workers} workers\n")
    session = AutotuneSession(search_space("capital-cholesky"),
                              backend=SimBackend(), tolerance=tol,
                              trials=3)
    t0 = time.time()
    results = session.sweep(policies=list(POLICIES), workers=workers)
    wall = time.time() - t0
    print(f"{'policy':13s} {'speedup':>8s} {'mean err':>9s} "
          f"{'optimum?':>9s} {'wall s':>7s}")
    for rep in results:
        print(f"{rep.policy:13s} {rep.speedup:8.2f} {rep.mean_error:9.3f} "
              f"{rep.optimum_quality:9.3f} {rep.wall_s:7.1f}")
    print(f"\nsweep wall time: {wall:.1f}s "
          f"(sum of per-study walls {sum(r.wall_s for r in results):.1f}s)")
    print("speedup   = full-execution tuning time / selective tuning time")
    print("mean err  = |predicted - measured| / measured, averaged")
    print("optimum?  = runtime of truly-best config / chosen config")


if __name__ == "__main__":
    main()
