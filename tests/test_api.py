"""Session-API contract tests.

- backend equivalence: ``AutotuneSession`` + ``SimBackend`` reproduces the
  seed engine's golden reports bit-for-bit (same pin as
  ``test_golden_reports``, but through the new front-end);
- parallel-sweep determinism: an N-worker fork-parallel sweep produces
  exactly the serial sweep's merged results;
- checkpoint/resume: partial studies and sweeps resume from JSON and land
  on results identical to an uninterrupted run;
- lossless JSON round-trips of ``ConfigRecord``/``StudyResult`` (tuples in
  params, infinities, NumPy scalars).
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (AutotuneSession, ConfigPoint, ConfigRecord,
                       SearchSpace, SimBackend, StudyResult,
                       WallClockBackend)
from repro.core.policies import POLICIES
from repro.core.signatures import comp_sig
from repro.core.tuner import space_of_study
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2

from golden_runner import GOLDEN_PATH, _studies

GOLDEN_FIELDS = ("full_time", "predicted", "rel_error", "comp_error",
                 "selective_cost", "full_cost", "executed", "skipped",
                 "predictions")


def _golden_backend():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    return SimBackend(timer=cm.sample)


def _strip_wall(result_json: dict) -> dict:
    d = dict(result_json)
    d.pop("wall_s", None)
    return d


# -- backend equivalence ------------------------------------------------------

def test_session_simbackend_reproduces_goldens():
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for study in _studies():
        space = space_of_study(study)
        for pol in POLICIES:
            session = AutotuneSession(space, backend=_golden_backend(),
                                      policy=pol, tolerance=0.25, trials=2)
            result = session.run()
            assert result.study == study.name
            assert result.backend == "sim"
            g_recs = golden[study.name][pol]
            assert len(result.records) == len(g_recs)
            got = json.loads(json.dumps(
                [r.to_json() for r in result.records]))
            for g, n in zip(g_recs, got):
                assert n["name"] == g["name"]
                for field in GOLDEN_FIELDS:
                    assert n[field] == g[field], \
                        f"{study.name}/{pol}/{g['name']}/{field}: " \
                        f"{n[field]!r} != {g[field]!r}"


# -- parallel sweep determinism ----------------------------------------------

def _tiny_session():
    study = _studies()[1]           # golden-capital: world 8, 2 configs
    return AutotuneSession(space_of_study(study),
                           backend=_golden_backend(), trials=2)


def test_parallel_sweep_matches_serial():
    # worker count is PINNED to 2, never derived from os.cpu_count():
    # on a single-core CI box a cpu-derived count degenerates to 1 and the
    # fork path silently goes untested.  run_tasks forks regardless of
    # core count, so 2 workers exercise it everywhere fork exists.
    from repro.api.parallel import fork_available
    assert fork_available(), \
        "no os.fork: the parallel sweep path cannot be exercised here"
    kw = dict(policies=["conditional", "eager"], tolerances=[1.0, 0.25])
    serial = _tiny_session().sweep(workers=1, **kw)
    forked = _tiny_session().sweep(workers=2, **kw)
    assert len(serial) == len(forked) == 4
    for s, p in zip(serial, forked):
        assert _strip_wall(s.to_json()) == _strip_wall(p.to_json())


# -- checkpoint / resume ------------------------------------------------------

def test_sweep_checkpoint_resume(tmp_path):
    ck = str(tmp_path / "sweep.json")
    kw = dict(policies=["conditional", "online"], tolerances=[0.25])
    # interrupted run: only the first sweep point completes
    first = _tiny_session().sweep(policies=["conditional"],
                                  tolerances=[0.25], checkpoint=ck)
    assert len(first) == 1
    # resumed run computes only the missing point and merges in grid order
    resumed = _tiny_session().sweep(checkpoint=ck, **kw)
    fresh = _tiny_session().sweep(**kw)
    assert len(resumed) == len(fresh) == 2
    # the checkpointed point is returned verbatim (wall_s included)
    assert resumed[0].to_json() == first[0].to_json()
    for a, b in zip(resumed, fresh):
        assert _strip_wall(a.to_json()) == _strip_wall(b.to_json())


class _FailingBackend(SimBackend):
    """Raises on the named configuration's reference run, once."""

    def __init__(self, fail_at: str, **kw):
        super().__init__(**kw)
        self.fail_at = fail_at
        self.tripped = False

    def open(self, *a, **kw):
        run = super().open(*a, **kw)
        orig = run.run_reference

        def ref(point):
            if not self.tripped and point.name == self.fail_at:
                self.tripped = True
                raise RuntimeError("interrupted")
            return orig(point)

        run.run_reference = ref
        return run


def test_study_checkpoint_resumes_partial_records(tmp_path):
    """Kill a study mid-run; the resumed study must be bit-identical to an
    uninterrupted one — including the sim RNG stream, which the journal
    carries across the interruption."""
    ck = str(tmp_path / "study.json")
    study = _studies()[0]           # golden-slate: resets between configs
    space = space_of_study(study)

    def session(backend):
        return AutotuneSession(space, backend=backend, policy="online",
                               tolerance=0.25, trials=2)

    reference = session(_golden_backend()).run()

    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    failing = _FailingBackend(space.points[1].name, timer=cm.sample)
    with pytest.raises(RuntimeError, match="interrupted"):
        session(failing).run(checkpoint=ck)
    # the journal holds config 0's record (+ RNG carry) — resume from it
    resumed = session(failing).run(checkpoint=ck)
    assert _strip_wall(resumed.to_json()) == \
        _strip_wall(reference.to_json())
    # a third run loads the completed result straight from the journal
    again = session(_golden_backend()).run(checkpoint=ck)
    assert again.to_json() == resumed.to_json()


# -- racing through the session front-end -------------------------------------

def test_racing_search_via_session():
    study = _studies()[1]
    session = AutotuneSession(space_of_study(study),
                              backend=_golden_backend(), policy="online",
                              tolerance=0.25, search="racing",
                              search_options={"max_rounds": 3})
    result = session.run()
    names = {p.name for p in session.space.points}
    assert result.search == "racing"
    assert result.extra["best"] in names
    assert set(result.extra["survivors"]) <= names
    assert result.extra["total_iterations"] <= 3 * len(names)
    assert all(r.predictions for r in result.records)
    # racing has no full-execution reference: the ratio metrics must be
    # NaN (not a crash, not a fake 0/inf) and the row must tabulate
    assert math.isnan(result.speedup)
    assert math.isnan(result.optimum_quality)
    row = result.row()
    assert row["selective_time"] == result.selective_tuning_time > 0


# -- wall-clock backend through the session -----------------------------------

def test_wallclock_backend_accounting():
    """Deterministic scripted clock: kernel A costs 1.0, kernel B 0.01;
    with a loose tolerance the timer must start skipping and the session's
    speedup/accounting must reflect the skipped executions."""
    sig_a, sig_b = comp_sig("ka", 1), comp_sig("kb", 2)
    now = [0.0]
    durations = {sig_a: 1.0, sig_b: 0.01}
    current = [None]

    def clock():
        return now[0]

    def make_thunk(sig):
        def thunk():
            now[0] += durations[sig]
        return thunk

    kernels = [(sig_a, make_thunk(sig_a), 1), (sig_b, make_thunk(sig_b), 1)]

    def kernels_of(point):
        return kernels

    space = SearchSpace(name="fake", points=[
        ConfigPoint(name="c0", params={"i": 0}),
        ConfigPoint(name="c1", params={"i": 1})])
    session = AutotuneSession(
        space, backend=WallClockBackend(kernels_of, clock=clock),
        policy="eager", tolerance=1.0, min_samples=2, trials=4)
    result = session.run()
    assert result.backend == "wallclock"
    assert len(result.records) == 2
    # eager keeps models across configs: by config c1 everything is skipped
    assert result.records[1].skipped > 0
    assert result.selective_tuning_time < result.full_tuning_time
    assert result.speedup > 1.0


def test_apriori_requires_sim_backend():
    def kernels_of(point):
        return []
    space = SearchSpace(name="fake", points=[ConfigPoint(name="c0")])
    session = AutotuneSession(space,
                              backend=WallClockBackend(kernels_of),
                              policy="apriori", tolerance=0.5)
    with pytest.raises(NotImplementedError):
        session.run()


# -- cross-process determinism -------------------------------------------------

_XPROC_SNIPPET = """
import json, sys
sys.path.insert(0, sys.argv[1]); sys.path.insert(0, sys.argv[2])
from repro.api import AutotuneSession, SimBackend
from repro.core.tuner import space_of_study
from golden_runner import _studies
res = AutotuneSession(space_of_study(_studies()[1]), backend=SimBackend(),
                      policy="online", tolerance=0.25, trials=2).run()
d = res.to_json(); d.pop("wall_s")
print(json.dumps(d, sort_keys=True))
"""


def test_cross_process_determinism_with_default_bias():
    """The DEFAULT cost model (bias_sigma > 0) must reproduce across
    interpreters with different hash seeds — the property checkpoint
    resume and recorded sweep artifacts rely on (the allocation bias is
    crc32-keyed, not hash()-keyed)."""
    here = os.path.dirname(__file__)
    src = os.path.join(here, os.pardir, "src")

    def run(hashseed):
        env = dict(os.environ, PYTHONHASHSEED=hashseed)
        out = subprocess.run(
            [sys.executable, "-c", _XPROC_SNIPPET, src, here],
            capture_output=True, text=True, env=env, check=True)
        return out.stdout.strip()

    assert run("1") == run("2")


# -- lossless serialization ---------------------------------------------------

def test_config_record_json_roundtrip_lossless():
    rec = ConfigRecord(
        name="cfg", params={"grid": (4, 8), "tile": np.int64(64),
                            "alpha": np.float64(0.5), "tag": "x",
                            "nested": {"dims": (1, (2, 3))},
                            "flags": [True, None]},
        full_time=1.25, predicted=float("inf"), rel_error=0.5,
        comp_error=0.0, selective_cost=0.75, full_cost=3.75,
        executed=10, skipped=2,
        predictions=[0.1, float("-inf"), 0.3],
        extra={"pruned_at": None})
    back = ConfigRecord.from_json(json.loads(json.dumps(rec.to_json())))
    assert back.params == {"grid": (4, 8), "tile": 64, "alpha": 0.5,
                           "tag": "x", "nested": {"dims": (1, (2, 3))},
                           "flags": [True, None]}
    assert isinstance(back.params["grid"], tuple)
    assert back.predicted == math.inf
    assert back.predictions == [0.1, -math.inf, 0.3]
    assert back == ConfigRecord.from_json(rec.to_json())


def test_study_result_json_roundtrip_lossless():
    rec = ConfigRecord(name="c", params={"b": (2, 3)}, full_time=1.0,
                       predicted=0.9, rel_error=0.1, comp_error=0.05,
                       selective_cost=0.5, full_cost=3.0, executed=4,
                       skipped=6, predictions=[0.8, 0.9])
    res = StudyResult(study="s", policy="online", tolerance=0.25,
                      records=[rec], full_tuning_time=3.0,
                      selective_tuning_time=0.5, backend="sim",
                      search="exhaustive", seed=1, allocation=2,
                      wall_s=0.1, extra={"survivors": ["c"]})
    back = StudyResult.from_json(json.loads(json.dumps(res.to_json())))
    assert back == res
    assert back.records[0].params["b"] == (2, 3)
    # StudyReport is the same class: the legacy name round-trips too
    from repro.core.tuner import StudyReport
    assert StudyReport is StudyResult


def test_serializer_rejects_unknown_types():
    from repro.api import to_jsonable
    with pytest.raises(TypeError):
        to_jsonable({"bad": object()})
