"""Always-on tuning daemon (repro.api.daemon + repro.serve.tuner) tests.

- a shape miss opens a study whose winner matches an offline
  ``LMStudy.session`` run under the same deterministic clock;
- a warm-started shape's study executes strictly fewer kernels than the
  cold one (fleet-store transfer);
- an injected kernel-cost shift trips the drift detector and the
  background re-tune lands a new winner while serving continues;
- daemon checkpoint kill/restore resumes with the fleet bank intact;
- a background re-tune through ``ForkExecutor`` is bit-identical to the
  in-process run;
- satellites: age-aware ``KernelStats`` discounting round-trips through
  JSON, the engine and the daemon share ONE bucketing function, and
  ``StatisticsBank.save`` is crash-atomic.
"""

import json
import os

import pytest

from repro.api import (AutotuneSession, ConfigPoint, DaemonConfig,
                       ForkExecutor, InProcessExecutor, RESET_POLICY,
                       SearchSpace, StatisticsBank, TuningDaemon,
                       WallClockBackend, fork_available)
from repro.api.daemon import DriftDetector, FleetStore, TUNED, TUNING
from repro.core.signatures import comp_sig, structural_key
from repro.core.stats import KernelStats
from repro.serve.engine import bucket_length
from repro.serve.tuner import VirtualClock, shape_key


def _stats_of(xs) -> KernelStats:
    ks = KernelStats()
    for x in xs:
        ks.update(x)
    return ks


# ------------------------------------------------- synthetic study provider

class SyntheticProvider:
    """Two-config studies over fake kernels with dict-driven costs.

    Every shape's step runs a fleet-shared kernel plus a per-(config,
    shape) kernel; costs come from a mutable dict, so a mid-run cost
    shift is one assignment.  Thunks advance the clock their context
    reads — serving thunks the daemon's serve clock, each study a FRESH
    per-study clock — so every measured value is an exact (cost + dt)
    independent of absolute clock state; fork and in-process study runs
    are therefore bit-identical.
    """

    def __init__(self, serve_clock, costs, *, trials: int = 2):
        self.serve_clock = serve_clock
        self.costs = costs
        self.trials = trials
        self.executions = 0     # ground-truth count of thunk invocations

    def _kernels(self, shape, which, clock):
        out = []
        for name, freq in (("shared", 2), (f"{which}-{shape}", 4)):
            sig = comp_sig(name)
            costs = self.costs

            def thunk(name=name):
                self.executions += 1
                clock.now += costs[name]
            out.extend([(sig, thunk, freq)] * freq)
        return out

    def _space(self, shape):
        pts = [ConfigPoint(name="A", params={"w": "a"},
                           payload=("a", shape)),
               ConfigPoint(name="B", params={"w": "b"},
                           payload=("b", shape))]
        return SearchSpace(name=f"syn-{shape}", points=pts,
                           reset_between_configs=RESET_POLICY)

    def session_for(self, key, meta, prior):
        clock = VirtualClock()

        def kernels_of(point):
            which, shape = getattr(point, "payload", point)
            return self._kernels(shape, which, clock)

        return AutotuneSession(
            self._space(meta["shape"]),
            backend=WallClockBackend(kernels_of, clock=clock),
            policy="eager", tolerance=0.5, min_samples=2,
            trials=self.trials, prior=prior, prior_discount=1.0,
            collect_stats=True)

    def kernels_for(self, key, meta, winner_name):
        return self._kernels(meta["shape"], winner_name.lower(),
                             self.serve_clock)

    def kernel_keys(self, key, meta, winner_name):
        return sorted({structural_key(s, 1) for s, _, _ in
                       self.kernels_for(key, meta, winner_name)})


def _daemon(costs=None, *, checkpoint=None, executor_factory=None):
    clock = VirtualClock()
    costs = dict(costs or {"shared": 1e-3,
                           "a-s1": 1e-3, "b-s1": 3e-3,
                           "a-s2": 1e-3, "b-s2": 3e-3})
    cfg = DaemonConfig(shadow_every=3, drift_z=3.0, drift_min_samples=2,
                       serve_min_samples=2, synchronous=True)
    d = TuningDaemon(SyntheticProvider(clock, costs), clock=clock,
                     config=cfg, checkpoint=checkpoint,
                     executor_factory=executor_factory)
    return d, clock, costs


def _tune(d, key, shape):
    info = d.serve(key, {"shape": shape})
    d.pump()
    return info


def _events(d, kind):
    return [e for e in d.events if e["event"] == kind]


# ----------------------------------------------------------- router + serve

def test_shape_miss_opens_study_then_serves_tuned():
    d, _, _ = _daemon()
    info = d.serve("k1", {"shape": "s1"})
    assert info["state"] == "miss" and info["winner"] is None
    assert d.pump() == 1
    info = d.serve("k1", {"shape": "s1"})
    assert info["state"] == TUNED
    assert info["winner"] == "A"          # cheaper per-config kernel
    # second occurrence: every winner kernel is banked and confident, so
    # the selective timer runs zero kernels and charges stored means
    assert info["executed"] == 0 and info["cold_banked"] == 0
    assert info["skipped"] > 0 and info["charged"] > 0.0


def test_daemon_winner_matches_offline_lm_session():
    """The daemon's shape-miss study converges to the same winner as an
    offline ``LMStudy.session`` run under the same deterministic clock."""
    from repro.serve.tuner import LMShapeProvider, ServingTuner
    from repro.tune.lm_study import LMStudy

    offline = LMStudy("smollm-135m", batch=2, seq=16).session(
        policy="eager", trials=2, max_configs=2,
        clock=VirtualClock(), collect_stats=True).run()

    tuner = ServingTuner(
        "smollm-135m", seq_buckets=(16,), clock=VirtualClock(),
        provider=LMShapeProvider(trials=2, max_configs=2,
                                 clock=VirtualClock()),
        config=DaemonConfig(shadow_every=3, serve_min_samples=2,
                            synchronous=True))
    assert tuner.serve_step(2, 16)["state"] == "miss"
    tuner.daemon.pump()
    info = tuner.serve_step(2, 16)
    assert info["state"] == TUNED
    assert info["winner"] == offline.chosen.name
    assert info["executed"] == 0 and info["cold_banked"] == 0
    assert tuner.knobs_for(2, 16).name == offline.chosen.name


def test_warm_started_shape_executes_fewer_kernels():
    d, _, _ = _daemon()
    prov = d.provider
    _tune(d, "k1", "s1")
    cold_execs = prov.executions
    _tune(d, "k2", "s2")        # warm: 'shared' is already banked
    warm_execs = prov.executions - cold_execs
    assert d.counters["warm_starts"] == 1
    started = _events(d, "tune_started")
    assert started[0]["warm"] is False and started[1]["warm"] is True
    assert 0 < warm_execs < cold_execs


def test_drift_detected_and_retune_lands_new_winner():
    d, _, costs = _daemon()
    _tune(d, "k1", "s1")
    assert d.winners["k1"]["name"] == "A"
    costs["a-s1"] = 10e-3                 # the winner's kernel got slow
    for _ in range(12):
        info = d.serve("k1", {"shape": "s1"})
        assert info["winner"] is not None     # serving never stops
        d.pump()
        if d.counters["retunes"]:
            break
    assert d.counters["drifts"] >= 1
    assert d.counters["retunes"] >= 1
    assert d.winners["k1"]["name"] == "B"     # re-tune flipped the winner
    names = [e["event"] for e in d.events]
    assert "drift_detected" in names and "retune_complete" in names
    retune = _events(d, "retune_complete")[-1]
    assert retune["previous"] == "A" and retune["winner"] == "B"


def test_drift_requires_min_samples_and_respects_ci():
    store = FleetStore(StatisticsBank(
        {"k": _stats_of([1.0, 1.1, 0.9, 1.0])}))
    det = DriftDetector(store, z=3.0, min_samples=3)
    assert det.observe("k", 5.0) is False     # 1 sample < min_samples
    assert det.observe("k", 5.0) is False
    assert det.observe("k", 5.0) is True      # live mean far outside CI
    # live samples matching the stored mean never drift
    det2 = DriftDetector(store, z=3.0, min_samples=3)
    assert not any(det2.observe("k", 1.0) for _ in range(10))
    # nothing stored -> nothing to drift from
    assert DriftDetector(store).observe("unknown", 9.9) is False


# ------------------------------------------------------ checkpoint / restore

def test_checkpoint_kill_restore_keeps_fleet_bank(tmp_path):
    ck = str(tmp_path / "daemon.json")
    d, _, costs = _daemon(checkpoint=ck)
    _tune(d, "k1", "s1")
    _tune(d, "k2", "s2")
    d.save_checkpoint()
    fp = d.fleet.bank.fingerprint()

    d2, _, _ = _daemon(costs, checkpoint=ck)  # "restart"
    assert d2.fleet.bank.fingerprint() == fp
    assert d2.winners == d.winners
    assert d2.state == {"k1": TUNED, "k2": TUNED}
    assert [e["event"] for e in d2.events][:len(d.events)] == \
        [e["event"] for e in d.events]
    info = d2.serve("k1", {"shape": "s1"})
    assert info["state"] == TUNED and info["executed"] == 0


def test_checkpoint_restore_resubmits_inflight_studies(tmp_path):
    ck = str(tmp_path / "daemon.json")
    d, _, costs = _daemon(checkpoint=ck)
    _tune(d, "k1", "s1")
    # open a study for k2 but "kill" the daemon before pumping the result
    d.serve("k2", {"shape": "s2"})
    assert d.state["k2"] == TUNING
    d.save_checkpoint()

    d2, _, _ = _daemon(costs, checkpoint=ck)
    d2.pump()                              # resubmitted study lands
    assert d2.state.get("k2") == TUNED
    assert d2.winners["k2"]["name"] == "A"


# ------------------------------------------------- fork-executor parity

@pytest.mark.skipif(not fork_available(), reason="no os.fork")
def test_fork_background_retune_bit_identical_to_inprocess():
    """A study forked to a worker must land the exact state an in-process
    run lands: every study starts from a fresh virtual clock and every
    fleet stamp comes off the parent-side serve clock, so the full
    snapshot — stats moments, winners, predicted times, counters, event
    journal including timestamps — is bit-identical across executors."""
    def flow(factory):
        d, _, costs = _daemon(executor_factory=factory)
        _tune(d, "k1", "s1")
        _tune(d, "k2", "s2")
        costs["a-s1"] = 10e-3
        for _ in range(12):
            d.serve("k1", {"shape": "s1"})
            d.pump()
            if d.counters["retunes"]:
                break
        d.pump()
        assert d.winners["k1"]["name"] == "B"
        return json.loads(json.dumps(d.snapshot()))

    inproc = flow(InProcessExecutor)
    forked = flow(lambda: ForkExecutor(1))
    assert forked == inproc


# --------------------------------------------------- satellite: age discount

def test_last_updated_roundtrips_and_keeps_old_banks_stable():
    st = _stats_of([1.0, 2.0, 3.0])
    st.last_updated = 123.5
    back = KernelStats.from_json(st.to_json())
    assert back.last_updated == 123.5
    assert back.copy().last_updated == 123.5
    # unstamped records serialize exactly as before (no new JSON field),
    # so pre-daemon banks keep their fingerprints
    assert "last_updated" not in _stats_of([1.0, 2.0]).to_json()
    bank = StatisticsBank({"k": _stats_of([1.0, 2.0])})
    fp = bank.fingerprint()
    bank.stamp(50.0)
    assert bank.fingerprint() != fp
    assert StatisticsBank.from_json(bank.to_json()) \
        .entries["k"].last_updated == 50.0


def test_discount_by_age_halves_evidence_per_half_life():
    st = _stats_of([1.0, 1.1, 0.9, 1.0, 1.05, 0.95, 1.0, 1.0])
    st.last_updated = 0.0
    aged = st.discount_by_age(100.0, 100.0)    # exactly one half-life
    assert aged.n == st.n // 2
    assert aged.mean == pytest.approx(st.mean)
    assert aged.variance == pytest.approx(st.variance)
    assert aged.ci_halfwidth() > st.ci_halfwidth()
    # unstamped evidence does not age; future stamps do not rejuvenate
    assert _stats_of([1.0, 2.0]).discount_by_age(1e9, 1.0).n == 2
    assert st.discount_by_age(-5.0, 1.0).n == st.n


def test_bank_discount_by_age_ttl_and_merge_stamps():
    young = _stats_of([1.0] * 4)
    young.last_updated = 90.0
    old = _stats_of([2.0] * 4)
    old.last_updated = 0.0
    bank = StatisticsBank({"young": young, "old": old})
    view = bank.discount_by_age(100.0, half_life=10.0, ttl=50.0)
    assert "old" not in view.entries            # beyond the TTL
    assert view.entries["young"].n == 2         # one half-life of age
    assert bank.entries["old"].n == 4           # source untouched
    # merge keeps the freshest stamp
    a, b = _stats_of([1.0]), _stats_of([2.0])
    a.last_updated, b.last_updated = 10.0, 20.0
    a.merge(b)
    assert a.last_updated == 20.0


# ------------------------------------------- satellite: unified bucketing

def test_engine_and_daemon_share_one_bucketing_function():
    from repro.serve.engine import Engine

    class _FakeEngine:
        class sc:
            prompt_buckets = (16, 32, 64)

    for n in (1, 16, 17, 32, 50, 64, 100):
        assert Engine._bucket(_FakeEngine(), n) == \
            bucket_length(n, (16, 32, 64))
    assert bucket_length(7, ()) == 7            # no buckets: identity
    assert bucket_length(100, (16, 32)) == 32   # clamped to the last
    # the daemon's shape keys bucket through the same function
    assert shape_key("smollm-135m", 2, bucket_length(24, (16, 32))) == \
        shape_key("smollm-135m", 2, 32)


# --------------------------------------------- satellite: crash-safe save

def test_bank_save_is_atomic_and_leaves_no_droppings(tmp_path,
                                                     monkeypatch):
    path = str(tmp_path / "bank.json")
    st = _stats_of([1.0, 2.0])
    st.last_updated = 7.0
    bank = StatisticsBank({"k": st})
    bank.save(path)
    loaded = StatisticsBank.load(path)
    assert loaded.fingerprint() == bank.fingerprint()
    assert loaded.entries["k"].last_updated == 7.0
    # a crash mid-save must leave the previous bank intact and no temp
    bank2 = StatisticsBank({"k": _stats_of([9.0, 9.0])})

    def boom(src, dst):
        raise OSError("disk went away")
    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        bank2.save(path)
    monkeypatch.undo()
    assert StatisticsBank.load(path).fingerprint() == bank.fingerprint()
    assert os.listdir(tmp_path) == ["bank.json"]


def test_fleet_store_record_prior_and_evict():
    clock = VirtualClock()
    fs = FleetStore(clock=clock, half_life=1e9)
    fs.record("k", 2.0)
    fs.record("k", 2.0)
    assert fs.reference("k").n == 2
    assert fs.reference("k").last_updated is not None
    assert len(fs.prior()) == 1
    assert fs.evict(["k", "missing"]) == 1
    assert fs.reference("k") is None
