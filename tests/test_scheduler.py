"""repro.api.scheduler contract tests.

- executor equivalence: serial (in-process), fork-pool, and localhost
  remote-worker sweeps produce identical results (same study, same
  winner, bit-identical records);
- deterministic sharing: a ``share_stats=True, deterministic=True`` fork
  sweep is bit-identical to the serial PR-2 golden sweep (and to the
  golden reports themselves);
- mid-sweep sharing: later-dispatched sweep points warm-start from
  earlier completions' banks (strictly fewer executed kernels, same
  winner) and the shared prior survives kill-and-resume through the
  checkpoint;
- the scheduler drives racing sweeps end-to-end;
- task lifecycle: explicit pending/running/done/failed states, failure
  propagation as ``SchedulerError``;
- fault tolerance: transient failures retried with attempt history and
  exponential backoff, ``on_failure="skip"`` partial sweeps, heartbeat
  and task-deadline liveness against protocol-stub workers, elastic
  mid-sweep worker join, worker survival across abrupt disconnects.
"""

import json
import os
import re
import socket
import struct
import subprocess
import sys
import threading
import time

import pytest

from repro.api import (AutotuneSession, RemoteExecutor, Scheduler,
                       SchedulerError, SimBackend, StatisticsBank)
from repro.api.scheduler import (DONE, FAILED, ForkExecutor,
                                 InProcessExecutor, fork_available)
from repro.core.policies import POLICIES
from repro.core.tuner import space_of_study
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2

from golden_runner import GOLDEN_PATH, _studies, golden_space

GOLDEN_FIELDS = ("full_time", "predicted", "rel_error", "comp_error",
                 "selective_cost", "full_cost", "executed", "skipped",
                 "predictions")


def _golden_backend():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    return SimBackend(timer=cm.sample)


def _capital_session(backend=None, **kw):
    return AutotuneSession(space_of_study(_studies()[1]),
                           backend=backend or _golden_backend(),
                           trials=2, **kw)


def _strip(result) -> dict:
    d = result.to_json()
    d.pop("wall_s", None)
    # recovery provenance is infrastructure history, not measurement
    d.get("extra", {}).pop("recovery", None)
    # program-cache counters are worker-configuration provenance (a remote
    # worker defaults to a warm cache, the serial driver runs without one);
    # replay is bit-identical, so measurements must still compare equal
    d.get("extra", {}).pop("program_cache", None)
    return d


# -- scheduler core ------------------------------------------------------------

def test_task_lifecycle_and_order():
    seen = []
    done_order = []

    def runner(payload):
        seen.append(payload)
        return {"value": payload * 10}

    tasks = Scheduler(InProcessExecutor(), runner).run(
        [3, 1, 2], on_done=lambda t: done_order.append(t.index))
    assert [t.state for t in tasks] == [DONE] * 3
    assert [t.result for t in tasks] == [{"value": 30}, {"value": 10},
                                         {"value": 20}]
    assert seen == [3, 1, 2]            # submission order == spec order
    assert done_order == [0, 1, 2]      # serial: completion == submission


def test_prepare_hook_late_binds_payloads():
    """Payloads are built at dispatch time, after earlier completions —
    the property mid-sweep statistics sharing rests on."""
    finished = []

    def prepare(task):
        return {"spec": task.spec, "seen": list(finished)}

    def runner(payload):
        finished.append(payload["spec"])
        return payload

    tasks = Scheduler(InProcessExecutor(), runner).run(
        ["a", "b", "c"], prepare=prepare)
    assert tasks[0].result["seen"] == []
    assert tasks[1].result["seen"] == ["a"]
    assert tasks[2].result["seen"] == ["a", "b"]


def test_failed_task_raises_with_state():
    def runner(payload):
        if payload == 1:
            raise ValueError("boom")
        return {"ok": payload}

    sched = Scheduler(InProcessExecutor(), runner)
    with pytest.raises(SchedulerError, match="boom") as ei:
        sched.run([0, 1, 2])
    assert ei.value.task.state == FAILED
    assert ei.value.task.index == 1
    assert "ValueError" in ei.value.task.error


@pytest.mark.skipif(not fork_available(), reason="no os.fork")
def test_fork_executor_matches_in_process():
    def runner(payload):
        return {"square": payload * payload}

    serial = Scheduler(InProcessExecutor(), runner).run(list(range(5)))
    forked = Scheduler(ForkExecutor(2), runner).run(list(range(5)))
    assert [t.result for t in serial] == [t.result for t in forked]
    assert all(t.state == DONE for t in forked)


def test_scheduler_raises_when_capacity_exhausted():
    """Losing every worker mid-sweep (RemoteExecutor shrinks capacity as
    workers drop) must raise, not return with tasks silently pending."""
    from repro.api.scheduler import Executor

    class _DyingExecutor(Executor):
        capacity = 1

        def start(self, runner):
            self._runner = runner

        def submit(self, index, payload):
            self._pending = (index, {"ok": self._runner(payload)})

        def poll(self):
            out = [self._pending]
            self.capacity = 0            # the only worker died while idle
            return out

    with pytest.raises(SchedulerError, match="no capacity"):
        Scheduler(_DyingExecutor(), lambda p: {"v": p}).run([1, 2, 3])


# -- retries and failure policy ------------------------------------------------

def test_retry_recovers_transient_failure():
    calls = {"n": 0}

    def runner(payload):
        if payload == 1:
            calls["n"] += 1
            if calls["n"] < 3:
                raise ValueError(f"flaky {calls['n']}")
        return {"v": payload}

    events = []
    tasks = Scheduler(InProcessExecutor(), runner, max_retries=2,
                      on_event=events.append).run([0, 1, 2])
    assert [t.state for t in tasks] == [DONE] * 3
    t = tasks[1]
    assert t.result == {"v": 1}
    assert t.meta["retries"] == 2
    assert [a["attempt"] for a in t.attempts] == [1, 2]
    assert "flaky 1" in t.attempts[0]["error"]
    assert t.attempts[0]["worker"] == "in-process"
    retries = [e for e in events if e["event"] == "task_retry"]
    assert [e["task"] for e in retries] == [1, 1]
    # tasks that never failed carry no history
    assert tasks[0].attempts == [] and "retries" not in tasks[0].meta


def test_retries_exhausted_raises_with_history():
    def runner(payload):
        raise ValueError("always boom")

    with pytest.raises(SchedulerError,
                       match=r"failed after 3 attempt") as ei:
        Scheduler(InProcessExecutor(), runner, max_retries=2).run([7])
    t = ei.value.task
    assert t.state == FAILED
    assert len(t.attempts) == 3
    msg = str(ei.value)
    assert "attempt 2 on in-process" in msg
    assert "always boom" in msg          # the last traceback rides along


def test_on_failure_skip_completes_rest_of_grid():
    def runner(payload):
        if payload == "bad":
            raise RuntimeError("persistent")
        return {"v": payload}

    events = []
    tasks = Scheduler(InProcessExecutor(), runner, max_retries=1,
                      on_failure="skip",
                      on_event=events.append).run(["a", "bad", "b"])
    assert [t.state for t in tasks] == [DONE, FAILED, DONE]
    assert tasks[1].result is None
    assert len(tasks[1].attempts) == 2
    assert tasks[0].result == {"v": "a"} and tasks[2].result == {"v": "b"}
    assert any(e["event"] == "task_failed" for e in events)


def test_interrupts_are_not_retried():
    """Ctrl-C must stop the sweep, not masquerade as a flaky task."""
    def runner(payload):
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        Scheduler(InProcessExecutor(), runner, max_retries=5).run([1])


def test_retry_backoff_is_exponential():
    def runner(payload):
        raise ValueError("nope")

    events = []
    with pytest.raises(SchedulerError):
        Scheduler(InProcessExecutor(), runner, max_retries=2,
                  retry_backoff=0.05, on_event=events.append).run([0])
    delays = [e["delay_s"] for e in events if e["event"] == "task_retry"]
    assert delays == [0.05, 0.1]


def test_invalid_on_failure_rejected():
    with pytest.raises(ValueError, match="on_failure"):
        Scheduler(InProcessExecutor(), on_failure="explode")


# -- executor equivalence on real sweeps ---------------------------------------

def test_serial_vs_fork_vs_remote_same_results(tmp_path):
    """The acceptance smoke: the same sweep through all three executors
    lands on identical results (the sim backend is seeded-deterministic
    across processes and machines)."""
    assert fork_available(), "fork executor cannot be exercised here"
    space = golden_space(1)

    def sess():
        # default SimBackend: the remote worker builds the same one
        return AutotuneSession(space, backend=SimBackend(), trials=2)

    kw = dict(policies=["conditional", "eager"], tolerances=[0.25])
    serial = [_strip(r) for r in sess().sweep(workers=1, **kw)]
    forked = [_strip(r) for r in sess().sweep(workers=2, **kw)]
    assert forked == serial

    with _worker(1) as addr:
        ex = RemoteExecutor([addr], expect={"space": space.name,
                                            "n_points": len(space)})
        remote = [_strip(r) for r in sess().sweep(executor=ex, **kw)]
    assert remote == serial
    winners = {json.dumps(r["records"], sort_keys=True) for r in serial}
    assert len(winners) <= len(serial)   # sanity: records present
    for r in serial:
        assert len(r["records"]) == len(space)


class _worker:
    """Launch ``python -m repro.api.worker`` serving the tiny golden
    Capital space — listening on an ephemeral localhost port, or dialing
    a listening executor (``connect=``, elastic-join mode)."""

    def __init__(self, index: int, once: bool = True,
                 connect: str = None):
        self.index = index
        self.once = once
        self.connect = connect
        self.proc = None

    def __enter__(self) -> str:
        here = os.path.dirname(__file__)
        src = os.path.abspath(os.path.join(here, os.pardir, "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
        cmd = [sys.executable, "-m", "repro.api.worker",
               "--spec", "golden_runner:golden_space",
               "--spec-args", json.dumps({"index": self.index})]
        if self.connect:
            cmd += ["--connect", self.connect]
        else:
            cmd += ["--port", "0"]
            if self.once:
                cmd += ["--once"]
        self.proc = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True, env=env)
        line = self.proc.stdout.readline()
        m = re.match(r"WORKER_READY (\S+) (\S+)", line)
        assert m, (f"worker failed to start: {line!r}\n"
                   f"{self.proc.stderr.read()}")
        if m.group(1) == "connect":
            return m.group(2)
        return f"{m.group(1)}:{m.group(2)}"

    def __exit__(self, *exc):
        self.proc.terminate()
        self.proc.wait(timeout=30)


def test_remote_worker_rejects_wrong_spec():
    with _worker(0) as addr:                    # serves golden-slate
        ex = RemoteExecutor([addr], expect={"space": "golden-capital"})
        with pytest.raises(SchedulerError, match="golden-slate"):
            ex.start(None)


def test_worker_answers_ping():
    """The ``{"op": "ping"}`` liveness heartbeat of the worker protocol."""
    with _worker(1) as addr:
        host, port = addr.rsplit(":", 1)
        with socket.create_connection((host, int(port)), timeout=10) as s:
            s.sendall(b'{"op": "ping"}\n{"op": "shutdown"}\n')
            f = s.makefile("rb")
            assert json.loads(f.readline()) == {"ok": "pong"}
            assert json.loads(f.readline()) == {"ok": "bye"}


def test_worker_survives_abrupt_disconnect():
    """A scheduler that vanishes mid-session (RST, not FIN) costs one
    connection, not the worker — the next scheduler connects fine."""
    with _worker(1, once=False) as addr:
        host, port = addr.rsplit(":", 1)
        s1 = socket.create_connection((host, int(port)), timeout=10)
        s1.sendall(b'{"op": "hello"}\n')
        assert b'"ok"' in s1.makefile("rb").readline()
        s1.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                      struct.pack("ii", 1, 0))
        s1.close()                                   # hard reset
        with socket.create_connection((host, int(port)), timeout=10) as s2:
            s2.sendall(b'{"op": "ping"}\n{"op": "shutdown"}\n')
            f = s2.makefile("rb")
            assert json.loads(f.readline()) == {"ok": "pong"}
            assert json.loads(f.readline()) == {"ok": "bye"}


def test_elastic_worker_joins_listening_executor():
    """``RemoteExecutor(listen=...)`` starts with zero workers; a
    ``--connect`` worker dials in mid-sweep and supplies the capacity."""
    space = golden_space(1)
    ex = RemoteExecutor(listen=0, join_timeout=30,
                        expect={"space": space.name})
    sess = AutotuneSession(space, backend=SimBackend(), trials=2)
    kw = dict(policies=["eager"], tolerances=[0.25])
    with _worker(1, connect=ex.listen_address):
        got = [_strip(r) for r in sess.sweep(executor=ex, **kw)]
    serial = [_strip(r) for r in AutotuneSession(
        space, backend=SimBackend(), trials=2).sweep(workers=1, **kw)]
    assert got == serial
    assert any(e["event"] == "worker_joined"
               for e in sess.last_sweep_events)


# -- liveness against protocol stubs -------------------------------------------

class _stub_worker:
    """Protocol-level stub for liveness tests: answers ``hello``, never
    answers ``ping``; ``run`` requests are echoed (``echo``) or silently
    swallowed (``wedge`` — alive but stuck)."""

    def __init__(self, mode: str = "echo"):
        self.mode = mode
        self.srv = socket.create_server(("127.0.0.1", 0))
        h, p = self.srv.getsockname()[:2]
        self.addr = f"{h}:{p}"
        self.thread = threading.Thread(target=self._serve, daemon=True)
        self.thread.start()

    def _serve(self):
        try:
            conn, _ = self.srv.accept()
        except OSError:
            return
        buf = bytearray()
        with conn:
            while True:
                try:
                    chunk = conn.recv(1 << 16)
                except OSError:
                    return
                if not chunk:
                    return
                buf += chunk
                while b"\n" in buf:
                    line, _, rest = bytes(buf).partition(b"\n")
                    buf[:] = rest
                    msg = json.loads(line)
                    op = msg.get("op")
                    if op == "hello":
                        conn.sendall(json.dumps(
                            {"ok": {"space": "stub", "n_points": 1,
                                    "backend": {}}}).encode() + b"\n")
                    elif op == "run" and self.mode == "echo":
                        conn.sendall(json.dumps(
                            {"id": msg["id"],
                             "ok": {"v": msg["task"]}}).encode() + b"\n")
                    # pings and wedged runs: no reply, ever

    def close(self):
        self.srv.close()


def test_heartbeat_drops_silent_idle_worker():
    """An idle worker that stops answering pings is dropped before a
    task is wasted on it."""
    w = _stub_worker()
    ex = RemoteExecutor([w.addr], heartbeat_interval=0.1)
    try:
        ex.start(None)
        assert ex.capacity == 1
        t0 = time.monotonic()
        ex._check_heartbeats(t0 + 0.2)      # idle past interval: ping out
        st, = ex._workers.values()
        assert st["ping_sent"] is not None
        ex._check_heartbeats(t0 + 0.4)      # unanswered a full interval
        assert ex.capacity == 0
        assert any(e["event"] == "heartbeat_timeout"
                   for e in ex.drain_events())
    finally:
        ex.close()
        w.close()


def test_task_deadline_reassigns_wedged_worker_task():
    """A wedged worker (socket open, no reply) trips the per-task
    deadline; its task is reassigned and the sweep completes — without
    the deadline, ``poll`` would block forever."""
    wedge, good = _stub_worker("wedge"), _stub_worker("echo")
    events = []
    ex = RemoteExecutor([wedge.addr, good.addr], task_timeout=0.5)
    try:
        tasks = Scheduler(ex, None, max_retries=1,
                          on_event=events.append).run([10, 20])
        assert [t.state for t in tasks] == [DONE, DONE]
        assert [t.result for t in tasks] == [{"v": 10}, {"v": 20}]
        retried, = [t for t in tasks if t.attempts]
        assert retried.meta["retries"] == 1
        assert "task deadline" in retried.attempts[0]["error"]
        names = {e["event"] for e in events}
        assert "task_deadline" in names and "task_retry" in names
    finally:
        wedge.close()
        good.close()


def test_remote_worker_task_error_propagates():
    space = golden_space(1)
    with _worker(1) as addr:
        session = AutotuneSession(space, backend=SimBackend(),
                                  search="racing", trials=1,
                                  search_options={"max_rounds": 0,
                                                  "bogus_option": True})
        with pytest.raises(SchedulerError, match="bogus_option"):
            session.sweep(executor=RemoteExecutor([addr]),
                          policies=["online"], tolerances=[0.25])


# -- deterministic sharing: golden parity --------------------------------------

@pytest.mark.skipif(not fork_available(), reason="no os.fork")
def test_deterministic_fork_share_sweep_matches_golden():
    """share_stats=True, deterministic=True with no checkpoint bank defers
    all sharing: a 2-worker fork sweep must be bit-identical to the serial
    driver AND to the PR-2 golden records."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    study = _studies()[1]
    kw = dict(policies=list(POLICIES), tolerances=[0.25])
    serial = _capital_session().sweep(workers=1, **kw)
    det = _capital_session().sweep(workers=2, share_stats=True,
                                   deterministic=True, **kw)
    assert [_strip(r) for r in det] == [_strip(r) for r in serial]
    for res in det:
        g_recs = golden[study.name][res.policy]
        got = json.loads(json.dumps([r.to_json() for r in res.records]))
        assert len(got) == len(g_recs)
        for g, n in zip(g_recs, got):
            assert n["name"] == g["name"]
            for field in GOLDEN_FIELDS:
                assert n[field] == g[field], \
                    f"{res.policy}/{g['name']}/{field}"


# -- mid-sweep statistics sharing ----------------------------------------------

def test_live_sharing_warm_starts_later_points():
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25, 0.0625])
    cold = _capital_session().sweep(workers=1, **kw)
    live = _capital_session().sweep(workers=1, share_stats=True, **kw)
    cold_exec = [sum(r.executed for r in res.records) for res in cold]
    live_exec = [sum(r.executed for r in res.records) for res in live]
    # the first point dispatches with no completions: identical to cold
    assert _strip(live[0]) == _strip(cold[0])
    # later points ride the shared prior: strictly fewer executions,
    # same winners
    assert sum(live_exec[1:]) < sum(cold_exec[1:])
    for c, l in zip(cold, live):
        assert l.chosen.name == c.chosen.name
    # sharing is scheduling-state, not result payload: no bank attached
    assert all("kernel_stats" not in res.extra for res in live)


def test_shared_results_never_replay_as_cold(tmp_path):
    ck = str(tmp_path / "ck.json")
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25])
    shared = _capital_session().sweep(workers=1, share_stats=True,
                                      checkpoint=ck, **kw)
    # a cold sweep over the same grid must NOT reuse the shared journal
    cold = _capital_session().sweep(workers=1, checkpoint=ck, **kw)
    fresh = _capital_session().sweep(workers=1, **kw)
    for c, f in zip(cold, fresh):
        assert _strip(c) == _strip(f)
    # while a repeated shared sweep DOES reuse it (wall_s included)
    again = _capital_session().sweep(workers=1, share_stats=True,
                                     checkpoint=ck, **kw)
    assert [r.to_json() for r in again] == [r.to_json() for r in shared]


class _FailNthOpen(SimBackend):
    """Fails the N-th ``open`` (0-indexed) once — kills sweep task N."""

    def __init__(self, fail_at: int, **kw):
        super().__init__(**kw)
        self.fail_at = fail_at
        self.opens = 0

    def open(self, *a, **kw):
        n = self.opens
        self.opens += 1
        if n == self.fail_at:
            raise RuntimeError("killed mid-sweep")
        return super().open(*a, **kw)


def test_kill_and_resume_restores_shared_prior(tmp_path):
    """A share_stats sweep killed mid-run resumes with the shared prior
    rebuilt from the checkpoint: the resumed run is bit-identical to an
    uninterrupted one (serial dispatch order makes the shared priors
    deterministic)."""
    from repro.api.session import _Checkpoint
    ck = str(tmp_path / "shared.json")
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25, 0.0625])

    uninterrupted = _capital_session().sweep(workers=1, share_stats=True,
                                             **kw)

    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    failing = _FailNthOpen(2, timer=cm.sample)
    with pytest.raises(SchedulerError, match="killed mid-sweep"):
        _capital_session(backend=failing).sweep(
            workers=1, share_stats=True, checkpoint=ck, **kw)

    # the checkpoint holds the first two points' results AND their
    # accumulated shared bank
    journal = _Checkpoint(ck)
    bank = journal.shared_bank()
    assert bank is not None and len(bank) > 0
    assert len(journal._data["results"]) == 2

    resumed = _capital_session().sweep(workers=1, share_stats=True,
                                       checkpoint=ck, **kw)
    assert [_strip(r) for r in resumed] == \
        [_strip(r) for r in uninterrupted]
    # the resumed third point really ran warm (not cold)
    cold = _capital_session().sweep(workers=1, policies=["eager"],
                                    tolerances=[0.0625])
    assert sum(r.executed for r in resumed[2].records) < \
        sum(r.executed for r in cold[0].records)


# -- racing through the scheduler ----------------------------------------------

def test_scheduler_drives_racing_sweeps():
    session = AutotuneSession(space_of_study(_studies()[1]),
                              backend=_golden_backend(), search="racing",
                              trials=1, search_options={"max_rounds": 3})
    kw = dict(policies=["online", "conditional"], tolerances=[0.25])
    serial = session.sweep(workers=1, **kw)
    names = {p.name for p in session.space.points}
    assert len(serial) == 2
    for r in serial:
        assert r.search == "racing"
        assert r.extra["best"] in names
    if fork_available():
        forked = AutotuneSession(
            space_of_study(_studies()[1]), backend=_golden_backend(),
            search="racing", trials=1,
            search_options={"max_rounds": 3}).sweep(workers=2, **kw)
        assert [_strip(r) for r in forked] == [_strip(r) for r in serial]


# -- run_tasks compat shim -----------------------------------------------------

def test_run_tasks_shim_preserves_contract():
    from repro.api.parallel import run_tasks
    landed = []
    out = run_tasks([1, 2, 3], lambda t: {"t": t}, workers=2,
                    on_result=lambda i, r: landed.append((i, r)))
    assert out == [{"t": 1}, {"t": 2}, {"t": 3}]
    assert sorted(landed) == [(0, {"t": 1}), (1, {"t": 2}), (2, {"t": 3})]
