"""repro.api.scheduler contract tests.

- executor equivalence: serial (in-process), fork-pool, and localhost
  remote-worker sweeps produce identical results (same study, same
  winner, bit-identical records);
- deterministic sharing: a ``share_stats=True, deterministic=True`` fork
  sweep is bit-identical to the serial PR-2 golden sweep (and to the
  golden reports themselves);
- mid-sweep sharing: later-dispatched sweep points warm-start from
  earlier completions' banks (strictly fewer executed kernels, same
  winner) and the shared prior survives kill-and-resume through the
  checkpoint;
- the scheduler drives racing sweeps end-to-end;
- task lifecycle: explicit pending/running/done/failed states, failure
  propagation as ``SchedulerError``.
"""

import json
import os
import re
import subprocess
import sys

import pytest

from repro.api import (AutotuneSession, RemoteExecutor, Scheduler,
                       SchedulerError, SimBackend, StatisticsBank)
from repro.api.scheduler import (DONE, FAILED, ForkExecutor,
                                 InProcessExecutor, fork_available)
from repro.core.policies import POLICIES
from repro.core.tuner import space_of_study
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2

from golden_runner import GOLDEN_PATH, _studies, golden_space

GOLDEN_FIELDS = ("full_time", "predicted", "rel_error", "comp_error",
                 "selective_cost", "full_cost", "executed", "skipped",
                 "predictions")


def _golden_backend():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    return SimBackend(timer=cm.sample)


def _capital_session(backend=None, **kw):
    return AutotuneSession(space_of_study(_studies()[1]),
                           backend=backend or _golden_backend(),
                           trials=2, **kw)


def _strip(result) -> dict:
    d = result.to_json()
    d.pop("wall_s", None)
    return d


# -- scheduler core ------------------------------------------------------------

def test_task_lifecycle_and_order():
    seen = []
    done_order = []

    def runner(payload):
        seen.append(payload)
        return {"value": payload * 10}

    tasks = Scheduler(InProcessExecutor(), runner).run(
        [3, 1, 2], on_done=lambda t: done_order.append(t.index))
    assert [t.state for t in tasks] == [DONE] * 3
    assert [t.result for t in tasks] == [{"value": 30}, {"value": 10},
                                         {"value": 20}]
    assert seen == [3, 1, 2]            # submission order == spec order
    assert done_order == [0, 1, 2]      # serial: completion == submission


def test_prepare_hook_late_binds_payloads():
    """Payloads are built at dispatch time, after earlier completions —
    the property mid-sweep statistics sharing rests on."""
    finished = []

    def prepare(task):
        return {"spec": task.spec, "seen": list(finished)}

    def runner(payload):
        finished.append(payload["spec"])
        return payload

    tasks = Scheduler(InProcessExecutor(), runner).run(
        ["a", "b", "c"], prepare=prepare)
    assert tasks[0].result["seen"] == []
    assert tasks[1].result["seen"] == ["a"]
    assert tasks[2].result["seen"] == ["a", "b"]


def test_failed_task_raises_with_state():
    def runner(payload):
        if payload == 1:
            raise ValueError("boom")
        return {"ok": payload}

    sched = Scheduler(InProcessExecutor(), runner)
    with pytest.raises(SchedulerError, match="boom") as ei:
        sched.run([0, 1, 2])
    assert ei.value.task.state == FAILED
    assert ei.value.task.index == 1
    assert "ValueError" in ei.value.task.error


@pytest.mark.skipif(not fork_available(), reason="no os.fork")
def test_fork_executor_matches_in_process():
    def runner(payload):
        return {"square": payload * payload}

    serial = Scheduler(InProcessExecutor(), runner).run(list(range(5)))
    forked = Scheduler(ForkExecutor(2), runner).run(list(range(5)))
    assert [t.result for t in serial] == [t.result for t in forked]
    assert all(t.state == DONE for t in forked)


def test_scheduler_raises_when_capacity_exhausted():
    """Losing every worker mid-sweep (RemoteExecutor shrinks capacity as
    workers drop) must raise, not return with tasks silently pending."""
    from repro.api.scheduler import Executor

    class _DyingExecutor(Executor):
        capacity = 1

        def start(self, runner):
            self._runner = runner

        def submit(self, index, payload):
            self._pending = (index, {"ok": self._runner(payload)})

        def poll(self):
            out = [self._pending]
            self.capacity = 0            # the only worker died while idle
            return out

    with pytest.raises(SchedulerError, match="no capacity"):
        Scheduler(_DyingExecutor(), lambda p: {"v": p}).run([1, 2, 3])


# -- executor equivalence on real sweeps ---------------------------------------

def test_serial_vs_fork_vs_remote_same_results(tmp_path):
    """The acceptance smoke: the same sweep through all three executors
    lands on identical results (the sim backend is seeded-deterministic
    across processes and machines)."""
    assert fork_available(), "fork executor cannot be exercised here"
    space = golden_space(1)

    def sess():
        # default SimBackend: the remote worker builds the same one
        return AutotuneSession(space, backend=SimBackend(), trials=2)

    kw = dict(policies=["conditional", "eager"], tolerances=[0.25])
    serial = [_strip(r) for r in sess().sweep(workers=1, **kw)]
    forked = [_strip(r) for r in sess().sweep(workers=2, **kw)]
    assert forked == serial

    with _worker(1) as addr:
        ex = RemoteExecutor([addr], expect={"space": space.name,
                                            "n_points": len(space)})
        remote = [_strip(r) for r in sess().sweep(executor=ex, **kw)]
    assert remote == serial
    winners = {json.dumps(r["records"], sort_keys=True) for r in serial}
    assert len(winners) <= len(serial)   # sanity: records present
    for r in serial:
        assert len(r["records"]) == len(space)


class _worker:
    """Launch ``python -m repro.api.worker`` serving the tiny golden
    Capital space on an ephemeral localhost port."""

    def __init__(self, index: int):
        self.index = index
        self.proc = None

    def __enter__(self) -> str:
        here = os.path.dirname(__file__)
        src = os.path.abspath(os.path.join(here, os.pardir, "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.api.worker",
             "--spec", "golden_runner:golden_space",
             "--spec-args", json.dumps({"index": self.index}),
             "--port", "0", "--once"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env)
        line = self.proc.stdout.readline()
        m = re.match(r"WORKER_READY (\S+) (\d+)", line)
        assert m, (f"worker failed to start: {line!r}\n"
                   f"{self.proc.stderr.read()}")
        return f"{m.group(1)}:{m.group(2)}"

    def __exit__(self, *exc):
        self.proc.terminate()
        self.proc.wait(timeout=30)


def test_remote_worker_rejects_wrong_spec():
    with _worker(0) as addr:                    # serves golden-slate
        ex = RemoteExecutor([addr], expect={"space": "golden-capital"})
        with pytest.raises(SchedulerError, match="golden-slate"):
            ex.start(None)


def test_remote_worker_task_error_propagates():
    space = golden_space(1)
    with _worker(1) as addr:
        session = AutotuneSession(space, backend=SimBackend(),
                                  search="racing", trials=1,
                                  search_options={"max_rounds": 0,
                                                  "bogus_option": True})
        with pytest.raises(SchedulerError, match="bogus_option"):
            session.sweep(executor=RemoteExecutor([addr]),
                          policies=["online"], tolerances=[0.25])


# -- deterministic sharing: golden parity --------------------------------------

@pytest.mark.skipif(not fork_available(), reason="no os.fork")
def test_deterministic_fork_share_sweep_matches_golden():
    """share_stats=True, deterministic=True with no checkpoint bank defers
    all sharing: a 2-worker fork sweep must be bit-identical to the serial
    driver AND to the PR-2 golden records."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    study = _studies()[1]
    kw = dict(policies=list(POLICIES), tolerances=[0.25])
    serial = _capital_session().sweep(workers=1, **kw)
    det = _capital_session().sweep(workers=2, share_stats=True,
                                   deterministic=True, **kw)
    assert [_strip(r) for r in det] == [_strip(r) for r in serial]
    for res in det:
        g_recs = golden[study.name][res.policy]
        got = json.loads(json.dumps([r.to_json() for r in res.records]))
        assert len(got) == len(g_recs)
        for g, n in zip(g_recs, got):
            assert n["name"] == g["name"]
            for field in GOLDEN_FIELDS:
                assert n[field] == g[field], \
                    f"{res.policy}/{g['name']}/{field}"


# -- mid-sweep statistics sharing ----------------------------------------------

def test_live_sharing_warm_starts_later_points():
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25, 0.0625])
    cold = _capital_session().sweep(workers=1, **kw)
    live = _capital_session().sweep(workers=1, share_stats=True, **kw)
    cold_exec = [sum(r.executed for r in res.records) for res in cold]
    live_exec = [sum(r.executed for r in res.records) for res in live]
    # the first point dispatches with no completions: identical to cold
    assert _strip(live[0]) == _strip(cold[0])
    # later points ride the shared prior: strictly fewer executions,
    # same winners
    assert sum(live_exec[1:]) < sum(cold_exec[1:])
    for c, l in zip(cold, live):
        assert l.chosen.name == c.chosen.name
    # sharing is scheduling-state, not result payload: no bank attached
    assert all("kernel_stats" not in res.extra for res in live)


def test_shared_results_never_replay_as_cold(tmp_path):
    ck = str(tmp_path / "ck.json")
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25])
    shared = _capital_session().sweep(workers=1, share_stats=True,
                                      checkpoint=ck, **kw)
    # a cold sweep over the same grid must NOT reuse the shared journal
    cold = _capital_session().sweep(workers=1, checkpoint=ck, **kw)
    fresh = _capital_session().sweep(workers=1, **kw)
    for c, f in zip(cold, fresh):
        assert _strip(c) == _strip(f)
    # while a repeated shared sweep DOES reuse it (wall_s included)
    again = _capital_session().sweep(workers=1, share_stats=True,
                                     checkpoint=ck, **kw)
    assert [r.to_json() for r in again] == [r.to_json() for r in shared]


class _FailNthOpen(SimBackend):
    """Fails the N-th ``open`` (0-indexed) once — kills sweep task N."""

    def __init__(self, fail_at: int, **kw):
        super().__init__(**kw)
        self.fail_at = fail_at
        self.opens = 0

    def open(self, *a, **kw):
        n = self.opens
        self.opens += 1
        if n == self.fail_at:
            raise RuntimeError("killed mid-sweep")
        return super().open(*a, **kw)


def test_kill_and_resume_restores_shared_prior(tmp_path):
    """A share_stats sweep killed mid-run resumes with the shared prior
    rebuilt from the checkpoint: the resumed run is bit-identical to an
    uninterrupted one (serial dispatch order makes the shared priors
    deterministic)."""
    from repro.api.session import _Checkpoint
    ck = str(tmp_path / "shared.json")
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25, 0.0625])

    uninterrupted = _capital_session().sweep(workers=1, share_stats=True,
                                             **kw)

    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    failing = _FailNthOpen(2, timer=cm.sample)
    with pytest.raises(SchedulerError, match="killed mid-sweep"):
        _capital_session(backend=failing).sweep(
            workers=1, share_stats=True, checkpoint=ck, **kw)

    # the checkpoint holds the first two points' results AND their
    # accumulated shared bank
    journal = _Checkpoint(ck)
    bank = journal.shared_bank()
    assert bank is not None and len(bank) > 0
    assert len(journal._data["results"]) == 2

    resumed = _capital_session().sweep(workers=1, share_stats=True,
                                       checkpoint=ck, **kw)
    assert [_strip(r) for r in resumed] == \
        [_strip(r) for r in uninterrupted]
    # the resumed third point really ran warm (not cold)
    cold = _capital_session().sweep(workers=1, policies=["eager"],
                                    tolerances=[0.0625])
    assert sum(r.executed for r in resumed[2].records) < \
        sum(r.executed for r in cold[0].records)


# -- racing through the scheduler ----------------------------------------------

def test_scheduler_drives_racing_sweeps():
    session = AutotuneSession(space_of_study(_studies()[1]),
                              backend=_golden_backend(), search="racing",
                              trials=1, search_options={"max_rounds": 3})
    kw = dict(policies=["online", "conditional"], tolerances=[0.25])
    serial = session.sweep(workers=1, **kw)
    names = {p.name for p in session.space.points}
    assert len(serial) == 2
    for r in serial:
        assert r.search == "racing"
        assert r.extra["best"] in names
    if fork_available():
        forked = AutotuneSession(
            space_of_study(_studies()[1]), backend=_golden_backend(),
            search="racing", trials=1,
            search_options={"max_rounds": 3}).sweep(workers=2, **kw)
        assert [_strip(r) for r in forked] == [_strip(r) for r in serial]


# -- run_tasks compat shim -----------------------------------------------------

def test_run_tasks_shim_preserves_contract():
    from repro.api.parallel import run_tasks
    landed = []
    out = run_tasks([1, 2, 3], lambda t: {"t": t}, workers=2,
                    on_result=lambda i, r: landed.append((i, r)))
    assert out == [{"t": 1}, {"t": 2}, {"t": 3}]
    assert sorted(landed) == [(0, {"t": 1}), (1, {"t": 2}), (2, {"t": 3})]
