"""Serving engine + selective-timer autotuning layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policies import policy
from repro.core.signatures import comp_sig
from repro.models.model import Model, ModelKnobs
from repro.serve.engine import Engine, Request, ServeConfig
from repro.tune.selective import SelectiveTimer

KNOBS = ModelKnobs(kv_chunk=16, ssm_chunk=8)


def test_engine_matches_manual_greedy():
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    n_new = 5

    # manual greedy decode
    lg, cache, t0 = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                  64, logits_at=jnp.asarray([len(prompt) - 1]))
    toks = [int(np.argmax(np.asarray(lg)[0]))]
    t = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([t], jnp.int32),
            {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)})
        toks.append(int(np.argmax(np.asarray(lg)[0])))
        t += 1

    eng = Engine(model, params, ServeConfig(batch_size=2, s_max=64,
                                            max_new_tokens=n_new))
    eng.submit(Request(0, prompt))
    res = eng.run()
    assert res[0].tokens[:n_new] == toks[:n_new]


def test_engine_multi_request_slots():
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(batch_size=2, s_max=64,
                                            max_new_tokens=4))
    for uid in range(5):     # more requests than slots -> queueing
        eng.submit(Request(uid, np.arange(3 + uid, dtype=np.int32)
                           % cfg.vocab))
    res = eng.run()
    assert len(res) == 5
    assert all(len(r.tokens) == 4 for r in res.values())


def test_selective_timer_skips_when_predictable():
    calls = {"n": 0}
    clk = {"t": 0.0}

    def clock():
        return clk["t"]

    def thunk():
        calls["n"] += 1
        clk["t"] += 1.0          # perfectly constant kernel

    timer = SelectiveTimer(policy("local", tolerance=0.2, min_samples=3),
                           clock=clock)
    sig = comp_sig("k", 1)
    for it in range(6):
        timer.begin_iteration()
        for _ in range(4):       # freq 4 per iteration
            timer.time_kernel(sig, thunk, freq=4)
    # constant timer: after min_samples the CI is ~0 -> later occurrences
    # skipped; 'local' policy still runs once per iteration
    assert calls["n"] < 24
    rep = timer.report()
    assert rep.skipped == 3 and rep.executed == 1


def test_selective_timer_eager_persists_across_configs():
    clk = {"t": 0.0}
    calls = {"n": 0}

    def clock():
        return clk["t"]

    def thunk():
        calls["n"] += 1
        clk["t"] += 1.0

    timer = SelectiveTimer(policy("eager", tolerance=0.2, min_samples=3),
                           clock=clock)
    sig = comp_sig("shared_kernel", 7)
    for cfg_idx in range(5):     # 5 "configurations" sharing the kernel
        timer.begin_iteration()
        for _ in range(3):
            timer.time_kernel(sig, thunk)
    assert sig in timer.global_off
    assert calls["n"] == 3       # never re-executed after switching off
