"""Cross-task event-program cache: bit-identity and durability.

PR 10 makes the recorded event program a serializable, content-addressed
artifact (``repro.simmpi.program``): a structural fingerprint over
(study key, world size, geometry params) keys an in-process LRU plus an
optional crash-atomic on-disk store, and a ``Runtime`` whose program
factory carries that fingerprint skips the structural recording pass on a
hit.  The gate is bit-identity: a cache-hit run must produce byte-equal
iteration reports, engine state, and sampler RNG stream to a cache-miss
run — across all five policies, the three op-mix-distinct studies, and
the straggler branch on AND off.  Durability: a corrupted or
version-stale artifact triggers a LOUD re-record (never a silent replay),
and concurrent workers sharing one cache directory never observe torn
writes.
"""

from __future__ import annotations

import json
import multiprocessing
import os

import pytest

from repro.core.critter import Critter
from repro.core.policies import POLICIES, policy
from repro.linalg import candmc_qr, capital_cholesky, slate_cholesky
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.program import (PROGRAM_VERSION, ProgramCache,
                                  program_from_payload, program_to_payload,
                                  structural_fingerprint)
from repro.simmpi.runtime import Runtime

REPORT_FIELDS = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                 "measured_time", "max_measured_comp", "executed",
                 "skipped", "events")

STUDIES = {
    "slate": (16, lambda w: slate_cholesky.make_program(
        w, n=512, tile=64, lookahead=1, pr=4, pc=4)),
    "capital": (8, lambda w: capital_cholesky.make_program(
        w, n=256, block=32, strategy=1, grid_c=2)),
    "candmc": (16, lambda w: candmc_qr.make_program(
        w, m=1024, n=128, block=16, pr=4, pc=4)),
}

FP = {name: structural_fingerprint(name, "p0", {"geom": name}, ws)
      for name, (ws, _) in STUDIES.items()}


def _state_snapshot(critter):
    S = critter.state
    return (S.mean_arr.tobytes(), S.freq.tobytes(), S.seen.tobytes(),
            S.skip_ok.tobytes(), S.iter_exec.tobytes(), S.clock.tobytes(),
            S.path_exec.tobytes(), S.path_comm.tobytes(),
            S.goff.tobytes(), S.gmean.tobytes(),
            sorted(critter.global_off),
            sorted((r, sid, st.n, st.mean, st.m2, st.total, st.min_t,
                    st.max_t)
                   for r in range(S.n_ranks)
                   for sid, st in S.kbar[r].items()))


def _run_protocol(study, pol, straggler_p, cache):
    """The tuner's per-configuration pattern (forced reference, selective
    trials, forced ``update_stats=False`` replay) under a fingerprint-
    stamped factory; ``cache=None`` is the uncached reference."""
    world_size, make = STUDIES[study]
    w = World(world_size)
    c = Critter(w, policy(pol, tolerance=0.25))
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                   straggler_p=straggler_p)
    rt = Runtime(w, c, cm.sample, seed=3, program_cache=cache)
    prog = make(w)
    if cache is not None:
        prog.program_key = FP[study]
    trace = []
    for i in range(4):
        res = rt.run(prog, force_execute=(i == 0))
        trace.append(tuple(getattr(res, f) for f in REPORT_FIELDS))
        trace.append(_state_snapshot(c))
    res = rt.run(prog, force_execute=True, update_stats=False)
    trace.append(tuple(getattr(res, f) for f in REPORT_FIELDS))
    trace.append(_state_snapshot(c))
    trace.append(rt._rng.bit_generator.state)
    return trace, rt


@pytest.mark.parametrize("study", sorted(STUDIES))
@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("straggler_p", [0.002, 0.0],
                         ids=["straggler-on", "straggler-off"])
def test_cache_hit_bit_identical(study, pol, straggler_p):
    """Miss (records + stores), hit (replays the artifact into a fresh
    World), and the uncached engine all produce byte-equal traces."""
    cache = ProgramCache()
    uncached, _ = _run_protocol(study, pol, straggler_p, None)
    miss, rt_miss = _run_protocol(study, pol, straggler_p, cache)
    assert rt_miss.recordings == 1 and rt_miss.cache_misses == 1
    hit, rt_hit = _run_protocol(study, pol, straggler_p, cache)
    assert rt_hit.recordings == 0 and rt_hit.cache_hits == 1
    for i, (u, m, h) in enumerate(zip(uncached, miss, hit)):
        assert u == m, (f"{study}/{pol}/straggler={straggler_p}: "
                        f"cache-MISS diverged at trace step {i}")
        assert u == h, (f"{study}/{pol}/straggler={straggler_p}: "
                        f"cache-HIT diverged at trace step {i}")


def test_disk_round_trip_bit_identical(tmp_path):
    """A program stored by one cache instance and loaded by another (fresh
    process simulation: cold LRU, disk only) replays bit-identically."""
    path = str(tmp_path / "progs")
    ref, _ = _run_protocol("slate", "conditional", 0.0, None)
    writer = ProgramCache(path)
    _run_protocol("slate", "conditional", 0.0, writer)
    assert writer.stores == 1 and os.listdir(path)
    reader = ProgramCache(path)
    got, rt = _run_protocol("slate", "conditional", 0.0, reader)
    assert rt.recordings == 0
    assert reader.disk_hits == 1 and reader.hits == 1
    assert got == ref


def test_fingerprint_is_structural():
    fp = structural_fingerprint("s", "p", {"n": 512, "tile": 64}, 16)
    assert fp == structural_fingerprint("s", "p", {"tile": 64, "n": 512},
                                        16)          # key order irrelevant
    assert fp.startswith(f"prog{PROGRAM_VERSION}:")
    others = [structural_fingerprint("s", "p", {"n": 512, "tile": 32}, 16),
              structural_fingerprint("s", "p", {"n": 512, "tile": 64}, 64),
              structural_fingerprint("s", "q", {"n": 512, "tile": 64}, 16),
              structural_fingerprint("t", "p", {"n": 512, "tile": 64}, 16)]
    assert len({fp, *others}) == 5


def test_payload_round_trip_equivalence():
    """Serialize from one World, materialize into a fresh one: identical
    event structure, signature tables, and communicator tables."""
    from repro.simmpi.ops import EV_BLOCK, EV_COLL
    ws, make = STUDIES["capital"]
    w1 = World(ws)
    rt = Runtime(w1, Critter(w1, policy("eager", 0.25)),
                 CostModel(KNL_STAMPEDE2).sample)
    before = len(w1._comms)
    prog = rt._compile_events(rt._record(make(w1)))
    comms = list(w1._comms)[before:]
    payload = program_to_payload(prog, w1.interner.sigs, comms)
    payload = json.loads(json.dumps(payload))        # full JSON round trip

    w2 = World(ws)
    loaded = program_from_payload(payload, w2)
    assert list(w1.interner.sigs) == list(w2.interner.sigs)
    assert list(w1._comms) == list(w2._comms)
    assert loaded.n_slots == prog.n_slots
    assert len(loaded.events) == len(prog.events)
    for a, b in zip(prog.events, loaded.events):
        assert a[0] == b[0]
        if a[0] == EV_BLOCK:
            assert a[1] == b[1] and a[2].sids == b[2].sids
        elif a[0] == EV_COLL:
            assert a[1] == b[1] and a[2].ranks == b[2].ranks
        else:
            assert a == b


def _corrupt(path, mutate):
    files = [f for f in os.listdir(path) if f.endswith(".json")]
    assert len(files) == 1
    f = os.path.join(path, files[0])
    with open(f) as fh:
        doc = json.load(fh)
    mutate(doc)
    with open(f, "w") as fh:
        json.dump(doc, fh)


@pytest.mark.parametrize("mutate, reason", [
    (lambda d: d["payload"]["events"].pop(), "checksum"),
    (lambda d: d.update(version=PROGRAM_VERSION + 1), "version"),
    (lambda d: d.update(fingerprint="prog1:deadbeef"), "fingerprint"),
    (lambda d: d.clear(), "not a program document"),
], ids=["corrupted-payload", "stale-version", "wrong-fingerprint",
        "emptied"])
def test_bad_artifact_rerecords_loudly(tmp_path, capsys, mutate, reason):
    """Every invalid on-disk artifact is refused with a stderr complaint
    and the engine re-records — results identical to an uncached run,
    never a silent replay of the bad artifact."""
    path = str(tmp_path / "progs")
    ref, _ = _run_protocol("capital", "local", 0.0, None)
    _run_protocol("capital", "local", 0.0, ProgramCache(path))
    _corrupt(path, mutate)
    capsys.readouterr()
    cache = ProgramCache(path)
    got, rt = _run_protocol("capital", "local", 0.0, cache)
    assert got == ref
    assert rt.recordings == 1, "bad artifact must force a re-record"
    assert cache.rejects == 1 and cache.misses == 1 and cache.hits == 0
    err = capsys.readouterr().err
    assert "falling back to re-recording" in err
    # the re-record republishes a valid artifact over the bad one
    assert ProgramCache(path).lookup(FP["capital"]) is not None


def test_unreadable_artifact_rerecords_loudly(tmp_path, capsys):
    path = str(tmp_path / "progs")
    os.makedirs(path)
    fname = FP["candmc"].replace(":", "_") + ".json"
    with open(os.path.join(path, fname), "w") as fh:
        fh.write('{"version": 1, "payload": ')       # torn mid-write
    ref, _ = _run_protocol("candmc", "apriori", 0.002, None)
    cache = ProgramCache(path)
    got, rt = _run_protocol("candmc", "apriori", 0.002, cache)
    assert got == ref and rt.recordings == 1 and cache.rejects == 1
    assert "falling back" in capsys.readouterr().err


def test_lru_eviction_and_adopt():
    cache = ProgramCache(capacity=2)
    ws, make = STUDIES["capital"]
    for i in range(3):
        w = World(ws)
        rt = Runtime(w, Critter(w, policy("conditional", 0.25)),
                     CostModel(KNL_STAMPEDE2).sample, program_cache=cache)
        prog = make(w)
        prog.program_key = f"prog1:{i:08x}"
        rt.run(prog, force_execute=True)
    assert len(cache) == 2                      # oldest evicted
    assert cache.lookup("prog1:00000000") is None
    assert cache.lookup("prog1:00000002") is not None
    # adopt_program: direct injection skips recording entirely
    w = World(ws)
    rt = Runtime(w, Critter(w, policy("conditional", 0.25)),
                 CostModel(KNL_STAMPEDE2).sample, program_cache=cache)
    adopted = cache.get("prog1:00000002", w)
    rt.adopt_program("prog1:deadbeef", adopted)
    prog = make(w)
    prog.program_key = "prog1:deadbeef"
    rt.run(prog, force_execute=True)
    assert rt.recordings == 0


# ------------------------------------------------- concurrent shared dir

def _hammer(args):
    """One simulated worker: alternately publish and load the same
    fingerprint against a shared cache directory.  Returns the number of
    validation rejects observed — any torn write would surface as one."""
    path, seed = args
    ws, make = STUDIES["capital"]
    w = World(ws)
    rt = Runtime(w, Critter(w, policy("conditional", 0.25)),
                 CostModel(KNL_STAMPEDE2).sample)
    before = len(w._comms)
    prog = rt._compile_events(rt._record(make(w)))
    comms = list(w._comms)[before:]
    fp = FP["capital"]
    cache = ProgramCache(path)
    loads = 0
    for i in range(20):
        if (i + seed) % 2:
            cache.put(fp, prog, w, comms=comms)
        else:
            cache._mem.clear()                  # force the disk path
            if cache.lookup(fp) is not None:
                loads += 1
    return cache.rejects, loads


def test_concurrent_workers_share_dir_without_torn_writes(tmp_path):
    path = str(tmp_path / "shared")
    ctx = multiprocessing.get_context("fork")
    with ctx.Pool(4) as pool:
        out = pool.map(_hammer, [(path, s) for s in range(4)])
    assert sum(r for r, _ in out) == 0, f"validation rejects: {out}"
    assert sum(l for _, l in out) > 0          # readers did hit disk
    assert ProgramCache(path).lookup(FP["capital"]) is not None


# ----------------------------------------------------- session integration

def test_sweep_records_once_per_geometry():
    """The acceptance counter end-to-end: a policy x tolerance sweep over
    one cached backend journals exactly one structural recording per
    unique geometry (first task records, every later task replays), and
    the results are bit-identical to the uncached sweep."""
    from golden_runner import golden_space
    from repro.api import AutotuneSession
    from repro.api.backends import SimBackend

    space = golden_space(1)
    kw = dict(policies=["conditional", "eager"], tolerances=[0.25, 0.1])

    cached = AutotuneSession(space, backend=SimBackend(program_cache="mem"),
                             trials=2)
    res = cached.sweep(**kw)
    pcs = [r.extra["program_cache"] for r in res]
    assert sum(p["recordings"] for p in pcs) == len(space.points)
    assert all(p["recordings"] == 0 for p in pcs[1:])
    assert all(p["hits"] == len(space.points) for p in pcs[1:])
    assert pcs[0]["fingerprints"].keys() == {p.name for p in space.points}
    evs = [e for e in cached.last_sweep_events
           if e.get("event") == "program_cache"]
    assert len(evs) == len(res)
    assert sum(e["recordings"] for e in evs) == len(space.points)

    plain = AutotuneSession(space, backend=SimBackend(), trials=2)

    def strip(r):
        d = r.to_json()
        d.pop("wall_s", None)
        d.get("extra", {}).pop("program_cache", None)
        return d

    assert [strip(a) for a in res] == [strip(b) for b in plain.sweep(**kw)]


def test_payload_fingerprint_mismatch_is_loud():
    """run_payload refuses a task whose dispatcher-side fingerprints
    disagree with what this (space, backend) computes — geometry drift
    must fail the task, not silently measure the wrong program."""
    from golden_runner import golden_space
    from repro.api.backends import SimBackend
    from repro.api.session import AutotuneSession, run_payload

    space = golden_space(1)
    backend = SimBackend(program_cache="mem")
    sess = AutotuneSession(space, backend=backend, trials=2)
    payload = sess._task_payload(("conditional", 0.25, 0, 0), None,
                                 collect=False, shared=False)
    fps = payload["program_fingerprints"]
    assert fps == backend.point_fingerprints(space)
    ok = run_payload(space, backend, json.loads(json.dumps(payload)))
    assert ok["policy"] == "conditional"

    drifted = dict(payload)
    drifted["program_fingerprints"] = {
        name: "prog1:00000bad" for name in fps}
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        run_payload(space, backend, drifted)
