"""simmpi runtime semantics + Critter protocol integration."""

import numpy as np
import pytest

from repro.core.critter import Critter
from repro.core.policies import policy
from repro.simmpi import Coll, Comp, Isend, Recv, Send, Wait
from repro.simmpi.comm import World
from repro.simmpi.runtime import DeadlockError, Runtime


def const_timer(t=1.0):
    return lambda sig, rng: t


def make_rt(world_size, pol="conditional", tol=0.25, timer=None, seed=0):
    w = World(world_size)
    c = Critter(w, policy(pol, tolerance=tol))
    rt = Runtime(w, c, timer or const_timer(), seed=seed, overhead=0.0)
    return w, c, rt


def test_bulk_synchronous_critical_path():
    """4 ranks: rank r does r+1 comps then an allreduce; wall time and
    critical path are determined by the slowest rank."""
    w, c, rt = make_rt(4)

    def prog(rank, world):
        for _ in range(rank + 1):
            yield Comp("gemm", (8, 8, 8))
        yield Coll("allreduce", world.world_comm, 64)

    res = rt.run(lambda r, w_: prog(r, w_), force_execute=True)
    # slowest rank: 4 comps (4s) + 1 comm (1s)
    np.testing.assert_allclose(res.wall_time, 5.0)
    np.testing.assert_allclose(res.predicted_time, 5.0)
    np.testing.assert_allclose(res.crit_comp, 4.0)
    np.testing.assert_allclose(res.crit_comm, 1.0)


def test_p2p_rendezvous_clock_sync():
    w, c, rt = make_rt(2)

    def prog(rank, world):
        if rank == 0:
            yield Comp("gemm", (8, 8, 8))   # 1s head start
            yield Send(1, 128)
        else:
            yield Recv(0, 128)
        yield Comp("gemm", (8, 8, 8))

    res = rt.run(lambda r, w_: prog(r, w_), force_execute=True)
    # recv completes at max(1, 0) + 1 = 2; both end at 3
    np.testing.assert_allclose(res.wall_time, 3.0)


def test_isend_does_not_block_sender():
    w, c, rt = make_rt(2)

    def prog(rank, world):
        if rank == 0:
            h = yield Isend(1, 64)
            for _ in range(3):
                yield Comp("gemm", (8, 8, 8))
            yield Wait(h)
        else:
            yield Comp("gemm", (8, 8, 8))
            yield Comp("gemm", (8, 8, 8))
            yield Recv(0, 64)

    res = rt.run(lambda r, w_: prog(r, w_), force_execute=True)
    # rank0: 3 comps after the isend -> busy until 3.
    # rank1: 2 comps (2s) + recv of buffered msg (1s) -> 3.
    np.testing.assert_allclose(res.wall_time, 3.0)


def test_collective_mismatch_raises():
    w, c, rt = make_rt(2)

    def prog(rank, world):
        if rank == 0:
            yield Coll("allreduce", world.world_comm, 64)
        else:
            yield Coll("bcast", world.world_comm, 64)

    with pytest.raises(RuntimeError, match="mismatch"):
        rt.run(lambda r, w_: prog(r, w_), force_execute=True)


def test_collective_byte_count_mismatch_raises():
    """The docstring contract: participants posting different byte counts
    at the same collective site is a schedule bug and raises."""
    w, c, rt = make_rt(2)

    def prog(rank, world):
        yield Coll("allreduce", world.world_comm, 64 if rank == 0 else 128)

    with pytest.raises(RuntimeError, match="byte-count mismatch"):
        rt.run(lambda r, w_: prog(r, w_), force_execute=True)


def test_deadlock_detection():
    w, c, rt = make_rt(2)

    def prog(rank, world):
        yield Recv(1 - rank, 64)   # both wait forever

    with pytest.raises(DeadlockError):
        rt.run(lambda r, w_: prog(r, w_), force_execute=True)


def test_selective_execution_skips_and_predicts():
    """With a constant timer, kernels become predictable after min_samples;
    later iterations skip them and the prediction stays exact."""
    w, c, rt = make_rt(4, pol="conditional", tol=0.5)

    def prog(rank, world):
        for _ in range(5):
            yield Comp("gemm", (16, 16, 16))
            yield Coll("allreduce", world.world_comm, 256)

    full = rt.run(lambda r, w_: prog(r, w_), force_execute=True)
    for _ in range(3):
        res = rt.run(lambda r, w_: prog(r, w_))
    assert res.skipped > 0
    np.testing.assert_allclose(res.predicted_time, full.wall_time,
                               rtol=1e-6)
    assert res.wall_time < full.wall_time


def test_online_counts_reduce_needed_samples():
    """Noisy timer: the online policy (sqrt(k) shrink from recurring
    kernels) skips more than conditional at the same tolerance."""
    def noisy(sig, rng):
        return float(np.exp(rng.normal(0.0, 0.15)))

    def prog(rank, world):
        for _ in range(40):
            yield Comp("gemm", (16, 16, 16))
        yield Coll("allreduce", world.world_comm, 256)

    skipped = {}
    for pol in ("conditional", "online"):
        w, c, rt = make_rt(2, pol=pol, tol=0.2, timer=noisy, seed=3)
        for _ in range(3):
            res = rt.run(lambda r, w_: prog(r, w_))
        skipped[pol] = res.skipped
    assert skipped["online"] >= skipped["conditional"]


def test_eager_switches_off_globally():
    w, c, rt = make_rt(4, pol="eager", tol=0.9)
    grids = w.grid_comms((2, 2))

    def prog(rank, world):
        row = grids.fiber(rank, 0)
        col = grids.fiber(rank, 1)
        for _ in range(6):
            yield Comp("gemm", (16, 16, 16))
            yield Coll("allreduce", row, 128)
            yield Coll("allreduce", col, 128)

    for _ in range(4):
        res = rt.run(lambda r, w_: prog(r, w_))
    assert len(c.global_off) > 0          # kernels switched off machine-wide
    assert res.skipped > 0
