"""Multi-device (8 virtual) integration: sharding rules, MoE EP dispatch,
compressed collectives, jaxdist algorithms, sharded train step."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import moe as MM
from repro.models.model import Model, ModelKnobs
from repro.parallel.sharding import axis_rules, make_rules
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import TrainConfig, make_train_step

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8,
                                reason="needs 8 virtual devices")

# jax 0.4.x lowering gaps, version-gated via the compat shim (see ROADMAP
# "jax 0.4.x gaps": revisit when the container jax is bumped, or add a
# ppermute-based fallback lowering).  The skip reasons below name the
# concrete failure so the skip report points at the ROADMAP item.
from repro.compat import HAS_AXIS_TYPES  # noqa: E402

skip_partial_manual = pytest.mark.skipif(
    not HAS_AXIS_TYPES,
    reason="jax 0.4.37 partial-manual shard_map gap (ROADMAP 'jax 0.4.x "
           "gaps'): shard_map over an axis_names subset lowers axis_index "
           "to PartitionId, which XLA SPMD rejects — requires jax >= 0.5")

skip_cholesky3d_miscompile = pytest.mark.skipif(
    not HAS_AXIS_TYPES,
    reason="jax 0.4.37 recursive-shard_map miscompile (ROADMAP 'jax 0.4.x "
           "gaps'): recursive composition of manual regions under "
           "re-sharding constraints miscompiles cholesky3d on 0.4.x SPMD "
           "— requires jax >= 0.5")


def test_rules_spec_dedup_and_fallback():
    mesh = make_host_mesh(model=4)        # (2, 4) data, model
    rules = make_rules("cp").with_mesh(mesh)
    # seq gets model; vocab (also model) must be dropped in the same spec
    s = rules.spec("batch", "seq", "vocab", dims=(4, 8, 12))
    assert s[1] == "model" and (len(s) < 3 or s[2] is None)
    # divisibility fallback: batch=1 cannot shard
    s2 = rules.spec("batch", None, dims=(1, 8))
    assert len(s2) == 0 or s2[0] is None
    # 'pod' axis silently dropped on a pod-less mesh
    assert all(ax in ("data", "model")
               for ax in (rules.mesh_axes("batch") or ()))


def test_rules_spec_properties():
    """Property test: for any logical-axes assignment and dims, the spec
    (a) never uses a mesh axis twice, (b) only shards divisible dims."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    mesh = make_host_mesh(model=4)        # (2, 4) data, model
    sizes = {"data": 2, "model": 4}
    logicals = ["batch", "seq", "ffn", "vocab", "embed", "tokens",
                "fsdp_embed", "expert", None]

    @given(st.lists(st.sampled_from(logicals), min_size=1, max_size=4),
           st.lists(st.integers(min_value=1, max_value=64), min_size=4,
                    max_size=4),
           st.sampled_from(["cp", "tp", "dp"]))
    @settings(max_examples=150, deadline=None)
    def check(axes, dims, variant):
        rules = make_rules(variant).with_mesh(mesh)
        spec = rules.spec(*axes, dims=dims[:len(axes)])
        used = []
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            es = (entry,) if isinstance(entry, str) else tuple(entry)
            prod = 1
            for ax in es:
                assert ax not in used, (spec, axes)
                used.append(ax)
                prod *= sizes[ax]
            assert dims[i] % prod == 0, (spec, axes, dims)

    check()


def test_moe_dispatch_equivalence_all_regimes():
    cfg = get_config("phi3.5-moe", reduced=True)
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    mesh = make_host_mesh(model=4)
    key = jax.random.PRNGKey(0)
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {"ln": jnp.zeros(D),
         "router": jax.random.normal(ks[0], (D, E)) * 0.1,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
         "w_up": jax.random.normal(ks[2], (E, D, F)) * 0.05,
         "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.05}
    x = jax.random.normal(ks[4], (8, 16, D))
    y_ref = jax.jit(lambda p, x: MM.moe_ffn(p, x, cfg, dispatch="sort"))(p, x)
    for variant in ("cp", "tp", "dp"):
        rules = make_rules(variant).with_mesh(mesh)
        with axis_rules(rules):
            y = jax.jit(
                lambda p, x: MM.moe_ffn(p, x, cfg, dispatch="a2a"))(p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)


def test_sharded_train_step_matches_unsharded():
    """One optimizer step on the mesh == the single-device step."""
    cfg = get_config("smollm-135m", reduced=True)
    knobs = ModelKnobs(kv_chunk=16, ssm_chunk=8)
    model = Model(cfg, knobs)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    B, S = 4, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    tc = TrainConfig(grad_accum=2,
                     optimizer=AdamWConfig(lr=1e-3, warmup=1))
    ref_step = jax.jit(make_train_step(model, None, tc))
    p_ref, o_ref, m_ref = ref_step(params, opt, batch)

    mesh = make_host_mesh(model=4)
    rules = make_rules("cp").with_mesh(mesh)
    sh_step = jax.jit(make_train_step(model, rules, tc))
    p_sh, o_sh, m_sh = sh_step(params, opt, batch)
    np.testing.assert_allclose(float(m_sh["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    l_ref = jax.tree.leaves(p_ref)
    l_sh = jax.tree.leaves(p_sh)
    for a, b in zip(l_ref, l_sh):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-4, atol=5e-5)


def test_grad_accum_invariance():
    """ga=1 and ga=4 produce the same update on the same global batch."""
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, ModelKnobs(kv_chunk=16, ssm_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    outs = {}
    for ga in (1, 4):
        tc = TrainConfig(grad_accum=ga,
                         optimizer=AdamWConfig(lr=1e-3, warmup=1))
        step = jax.jit(make_train_step(model, None, tc))
        p, _, m = step(params, opt, batch)
        outs[ga] = (p, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]),
                    jax.tree.leaves(outs[4][0])):
        # microbatched mean reassociates float reductions: loose tolerance
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-4)


@skip_partial_manual
def test_pipeline_parallel_matches_reference():
    """GPipe-style pipeline over 'pod': loss and grads match the plain
    model (exact schedule equivalence through ppermute transposes)."""
    from repro.parallel.pipeline import pipeline_loss
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, ModelKnobs(kv_chunk=16, ssm_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    ref = float(jax.jit(model.loss)(params, batch))
    mesh = make_host_mesh(model=2, pod=2)
    rules = make_rules("cp").with_mesh(mesh)
    got = float(jax.jit(
        lambda p, b: pipeline_loss(model, rules, p, b, n_mb=4))(
            params, batch))
    np.testing.assert_allclose(got, ref, rtol=2e-3)
    g = jax.jit(jax.grad(
        lambda p, b: pipeline_loss(model, rules, p, b, n_mb=4)))(
            params, batch)
    g_ref = jax.jit(jax.grad(model.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-3, atol=1e-4)


def test_int8_ring_allreduce():
    from repro.parallel.compression import ring_allreduce_int8
    mesh = make_host_mesh(model=1)        # (8,) pure data... (8,1)
    from repro.compat import AxisType, make_mesh
    mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    x = np.random.default_rng(0).standard_normal((8, 777)) \
        .astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("data")))
    out = np.asarray(jax.jit(
        lambda a: ring_allreduce_int8(a, mesh, "data"))(xs))
    ref = x.sum(0)
    scale = np.abs(ref).max()
    for r in range(8):
        assert np.abs(out[r] - ref).max() / scale < 0.05


def test_error_feedback_reduces_bias():
    """With error feedback, compressed grad sums converge to the true sum
    over repeated steps (residual reinjection)."""
    from repro.parallel.compression import ErrorFeedback
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    resid = ErrorFeedback.init(g_true)
    acc_c = np.zeros(4096)
    for i in range(20):
        c, resid = ErrorFeedback.apply(g_true, resid)
        acc_c += np.asarray(c)
    err = np.abs(acc_c - 20 * np.asarray(g_true)).max()
    assert err < 0.05 * np.abs(20 * np.asarray(g_true)).max()


def test_jaxdist_algorithms():
    from repro.jaxdist import make_3d_mesh, matmul_3d, tsqr
    mesh = make_3d_mesh(2)
    rng = np.random.default_rng(0)
    A = rng.standard_normal((32, 64)).astype(np.float32)
    B = rng.standard_normal((64, 16)).astype(np.float32)
    a = jax.device_put(A, NamedSharding(mesh, P("x", "z")))
    b = jax.device_put(B, NamedSharding(mesh, P("z", "y")))
    C = np.asarray(jax.jit(lambda a, b: matmul_3d(a, b, mesh))(a, b))
    np.testing.assert_allclose(C, A @ B, rtol=1e-4, atol=1e-4)

    Am = rng.standard_normal((64, 8)).astype(np.float32)
    am = jax.device_put(Am, NamedSharding(mesh, P("x", None)))
    Q, R = jax.jit(lambda a: tsqr(a, mesh, "x"))(am)
    np.testing.assert_allclose(np.asarray(Q) @ np.asarray(R), Am,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(Q).T @ np.asarray(Q),
                               np.eye(8), atol=1e-4)


@skip_cholesky3d_miscompile
def test_jaxdist_cholesky3d():
    from repro.jaxdist import cholesky_3d, make_3d_mesh
    mesh = make_3d_mesh(2)
    rng = np.random.default_rng(0)
    n = 32
    M = rng.standard_normal((n, n)).astype(np.float32)
    SPD = M @ M.T + n * np.eye(n, dtype=np.float32)
    aa = jax.device_put(SPD, NamedSharding(mesh, P("x", "y")))
    L, Linv = jax.jit(lambda a: cholesky_3d(a, mesh, block=8))(aa)
    np.testing.assert_allclose(np.asarray(L) @ np.asarray(L).T, SPD,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(L) @ np.asarray(Linv),
                               np.eye(n), atol=2e-3)
