"""Autotuner behaviour on the paper's case studies (CI scale, fast subsets)."""

import numpy as np
import pytest

from repro.core.policies import policy
from repro.core.tuner import Autotuner, Configuration, Study
from repro.linalg import capital_cholesky
from repro.linalg.studies import capital_cholesky_study
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2


def tiny_capital_study(n_configs=4):
    full = capital_cholesky_study("ci")
    return Study(name="tiny-capital", world_size=full.world_size,
                 configs=full.configs[:n_configs],
                 reset_between_configs=False, machine=full.machine)


def test_exhaustive_tuner_speedup_and_optimum():
    study = tiny_capital_study()
    tuner = Autotuner(study, policy("eager", tolerance=0.3), trials=3,
                      seed=0)
    rep = tuner.tune()
    assert rep.speedup > 1.5
    assert rep.optimum_quality >= 0.95
    assert all(r.rel_error < 0.6 for r in rep.records)


def test_error_decreases_with_tolerance():
    errs = {}
    for tol in (1.0, 0.05):
        study = tiny_capital_study()
        tuner = Autotuner(study, policy("online", tolerance=tol),
                          trials=3, seed=1)
        rep = tuner.tune()
        errs[tol] = rep.mean_error
    assert errs[0.05] <= errs[1.0] + 0.02


def test_apriori_charges_offline_pass():
    study = tiny_capital_study(2)
    t_apriori = Autotuner(study, policy("apriori", tolerance=0.3),
                          trials=2, seed=0).tune()
    study2 = tiny_capital_study(2)
    t_cond = Autotuner(study2, policy("conditional", tolerance=0.3),
                       trials=2, seed=0).tune()
    # the offline pass is charged to apriori's autotuning time
    assert t_apriori.selective_tuning_time > \
        0.9 * t_cond.selective_tuning_time


def test_racing_prunes_and_finds_optimum():
    study = tiny_capital_study()
    tuner = Autotuner(study, policy("online", tolerance=0.3), trials=1,
                      seed=0)
    rep = tuner.tune_racing(max_rounds=6)
    # racing must not benchmark every config every round
    assert rep.total_iterations < 6 * len(study.configs)
    assert rep.best in {c.name for c in study.configs}


def test_extrapolate_policy_skips_more():
    """policy(extrapolate=True) must not lose the optimum and should skip
    at least as many kernels as the plain policy (CANDMC subset)."""
    from repro.linalg.studies import candmc_qr_study

    reps = {}
    for extra in (False, True):
        full = candmc_qr_study("ci")
        study = Study(name="candmc-sub", world_size=full.world_size,
                      configs=full.configs[:3], reset_between_configs=True,
                      machine=full.machine)
        rep = Autotuner(study, policy("online", tolerance=0.3,
                                      extrapolate=extra),
                        trials=2, seed=0).tune()
        reps[extra] = rep
    assert reps[True].optimum_quality >= 0.99
    sel = {k: r.selective_tuning_time for k, r in reps.items()}
    assert sel[True] <= sel[False] * 1.05


def test_cost_model_allocation_bias_reproducible():
    cm0 = CostModel(KNL_STAMPEDE2, allocation=0, seed=5)
    cm0b = CostModel(KNL_STAMPEDE2, allocation=0, seed=5)
    cm1 = CostModel(KNL_STAMPEDE2, allocation=1, seed=5)
    from repro.core.signatures import comp_sig
    sig = comp_sig("gemm", 64, 64, 64)
    assert cm0._bias_of(sig) == cm0b._bias_of(sig)
    assert cm0._bias_of(sig) != cm1._bias_of(sig)
    rng = np.random.default_rng(0)
    ts = [cm0.sample(sig, rng) for _ in range(50)]
    assert np.std(ts) > 0          # noise present
    assert min(ts) > 0
