"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, supported_shapes
from repro.models.model import Model, ModelKnobs

KNOBS = ModelKnobs(kv_chunk=16, ssm_chunk=8)


def make_batch(cfg, B=2, S=32, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    tshape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jax.random.randint(key, tshape, 0, cfg.vocab),
             "labels": jax.random.randint(key, tshape, 0, cfg.vocab)}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_loss_decode(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    logits = model.forward(params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits)))
    # one decode step from an empty cache
    cache = model.init_cache(2, 64)
    tok = batch["tokens"][:, :1]
    lg, cache2 = jax.jit(model.decode_step)(params, cache, jnp.int32(0),
                                            {"tokens": tok})
    assert np.all(np.isfinite(np.asarray(lg)))


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v2-236b",
                                  "jamba-v0.1-52b", "xlstm-125m",
                                  "musicgen-large"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode after prefill must reproduce the full-sequence
    forward logits — validates every cache layout (KV, latent, conv, ssm,
    mlstm, slstm) and the decode attention masks."""
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        # capacity drops legitimately differ between prompt lengths; kill
        # drops so the cache-consistency comparison is exact
        from dataclasses import replace as drep
        cfg = drep(cfg, moe=drep(cfg.moe, capacity_factor=64.0))
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(1))
    B, S, S_pre = 2, 16, 8
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(2))
    full_logits = np.asarray(model.forward(params, batch))
    if cfg.n_patches:   # decode positions offset by the patch prefix
        pytest.skip("vlm decode covered via smoke (patch prefix offsets)")

    toks = batch["tokens"]
    lg, cache, t0 = jax.jit(lambda p, b: model.prefill(p, b, S))(
        params, {"tokens": toks[:, :S_pre]})
    np.testing.assert_allclose(np.asarray(lg),
                               full_logits[:, S_pre - 1], rtol=2e-2,
                               atol=2e-3)
    step = jax.jit(model.decode_step)
    for t in range(S_pre, S):
        lg, cache = step(params, cache, jnp.int32(t),
                         {"tokens": toks[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(lg), full_logits[:, t],
                                   rtol=2e-2, atol=2e-3)


def test_vlm_prefill_decode_matches_forward():
    """internvl2: decode after a (patches + text) prefill reproduces the
    full-sequence forward logits — validates the patch-prefix position
    offsets through the cache."""
    cfg = get_config("internvl2-2b", reduced=True)
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(1))
    B, S_text, S_pre = 2, 12, 6
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, S_text), 0, cfg.vocab)
    patches = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model)) * 0.1
    full_logits = np.asarray(model.forward(
        params, {"tokens": toks, "patches": patches}))
    P_ = cfg.n_patches
    s_max = P_ + S_text + 4
    lg, cache, t0 = model.prefill(
        params, {"tokens": toks[:, :S_pre], "patches": patches}, s_max)
    np.testing.assert_allclose(np.asarray(lg),
                               full_logits[:, P_ + S_pre - 1],
                               rtol=2e-2, atol=2e-3)
    step = jax.jit(model.decode_step)
    for i in range(S_pre, S_text):
        t = P_ + i                      # absolute position in the cache
        lg, cache = step(params, cache, jnp.int32(t),
                         {"tokens": toks[:, i:i + 1]})
        np.testing.assert_allclose(np.asarray(lg), full_logits[:, P_ + i],
                                   rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, KNOBS)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    g = jax.jit(jax.grad(model.loss))(params, batch)
    gnorm = float(jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                               for l in jax.tree.leaves(g))))
    assert np.isfinite(gnorm) and gnorm > 0


def test_long_500k_skip_policy():
    runnable = {a: supported_shapes(get_config(a)) for a in ARCHS}
    assert "long_500k" in runnable["xlstm-125m"]
    assert "long_500k" in runnable["jamba-v0.1-52b"]
    assert "long_500k" not in runnable["yi-34b"]
    total = sum(len(v) for v in runnable.values())
    assert total == 32          # 10*3 + 2 runnable cells


def test_mlstm_chunkwise_matches_recurrent():
    """The chunkwise-parallel mLSTM (EXPERIMENTS.md §Perf H2-k) is an exact
    reformulation: outputs AND carried state match the recurrent oracle."""
    from repro.models import ssm as S
    cfg = get_config("xlstm-125m", reduced=True)
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 9)
    D, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    p = {"ln": jnp.zeros(D),
         "up": jax.random.normal(ks[0], (D, 2 * di)) * 0.05,
         "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di)) * 0.1,
         "wq": jax.random.normal(ks[2], (di, di)) * 0.05,
         "wk": jax.random.normal(ks[3], (di, di)) * 0.05,
         "wv": jax.random.normal(ks[4], (di, di)) * 0.05,
         "wif": jax.random.normal(ks[5], (di, 2 * nh)) * 0.5,
         "b_if": jax.random.normal(ks[6], (2 * nh,)) * 0.5,
         "down": jax.random.normal(ks[7], (di, D)) * 0.05}
    x = jax.random.normal(ks[8], (2, 48, D))
    y_r, (_, st_r) = S.mlstm_block(p, x, cfg, chunk=16, mode="recurrent")
    y_c, (_, st_c) = S.mlstm_block(p, x, cfg, chunk=16, mode="chunkwise")
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(st_r, st_c):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


def test_remat_matches_no_remat():
    cfg = get_config("smollm-135m", reduced=True)
    batch = make_batch(cfg)
    p = Model(cfg, KNOBS).init(jax.random.PRNGKey(0))
    l1 = Model(cfg, KNOBS).loss(p, batch)
    from dataclasses import replace
    l2 = Model(cfg, replace(KNOBS, remat="none")).loss(p, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
