"""Checkpoint/restart: atomicity, retention, bitwise resume, elastic
resharding onto a different mesh."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model, ModelKnobs
from repro.parallel.sharding import make_rules
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import (TrainConfig, make_train_step,
                              param_shardings, shard_params)
from repro.configs.base import Shape


def _setup(tmp):
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, ModelKnobs(kv_chunk=16, ssm_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup=1))
    step = jax.jit(make_train_step(model, None, tc))
    shape = Shape("t", 32, 4, "train")
    return cfg, model, params, opt, step, shape


def _run(cfg, shape, step, params, opt, a, b):
    for i in range(a, b):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, shape, i).items()}
        params, opt, m = step(params, opt, batch)
    return params, opt, float(m["loss"])


def test_restart_bitwise_identical(tmp_path):
    cfg, model, params, opt, step, shape = _setup(tmp_path)
    d = str(tmp_path / "ck")
    # run 6 steps straight
    p6, o6, l6 = _run(cfg, shape, step, params, opt, 0, 6)
    # run 3, checkpoint, restore, run 3 more
    p3, o3, _ = _run(cfg, shape, step, params, opt, 0, 3)
    ckpt.save(d, 3, {"params": p3, "opt": o3})
    like = {"params": jax.eval_shape(lambda: p3),
            "opt": jax.eval_shape(lambda: o3)}
    tree, man = ckpt.restore(d, 3, like)
    assert man["step"] == 3
    pr, orr = tree["params"], tree["opt"]
    p6b, o6b, l6b = _run(cfg, shape, step,
                         jax.tree.map(jnp.asarray, pr),
                         jax.tree.map(jnp.asarray, orr), 3, 6)
    assert l6 == l6b    # bitwise-identical continuation
    for a, b in zip(jax.tree.leaves(p6), jax.tree.leaves(p6b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.all_steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5


def test_elastic_reshard(tmp_path):
    """Save from an (8,)-data mesh, restore onto a (2,4) mesh."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    cfg = get_config("smollm-135m", reduced=True)
    model = Model(cfg, ModelKnobs(kv_chunk=16, ssm_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    mesh_a = make_host_mesh(model=1)      # (8, 1)
    rules_a = make_rules("cp").with_mesh(mesh_a)
    pa = shard_params(model, params, rules_a)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, pa)

    mesh_b = make_host_mesh(model=4)      # (2, 4)
    rules_b = make_rules("cp").with_mesh(mesh_b)
    sh_b = param_shardings(model, rules_b)
    like = jax.eval_shape(lambda: params)
    pb, _ = ckpt.restore(d, 1, like, shardings=sh_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the restored tree really lives on mesh_b
    leaf = jax.tree.leaves(pb)[0]
    assert leaf.sharding.mesh.shape == mesh_b.shape


def test_atomic_no_partial_checkpoint(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, {"x": jnp.ones(8)})
    entries = [e for e in os.listdir(d) if e.startswith(".tmp")]
    assert not entries          # tmp dirs cleaned up / renamed
    tree, _ = ckpt.restore(d, 7, {"x": jax.ShapeDtypeStruct((8,),
                                                            jnp.float32)})
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.ones(8))
