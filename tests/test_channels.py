"""core.channels: cartesian factorization + aggregate closure."""

import pytest

try:                                    # hypothesis is an optional test dep:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # property tests skip, the rest run
    HAVE_HYPOTHESIS = False

from repro.core.channels import Channel, ChannelRegistry, ranks_to_channel


def test_factorization_roundtrip_grid():
    # rows/cols/fibers of a 4x4x4 grid all factor and reproduce their ranks
    for ranks in ([0, 1, 2, 3], [0, 4, 8, 12], [0, 16, 32, 48],
                  [5, 21, 37, 53], list(range(64))):
        ch = ranks_to_channel(ranks)
        assert ch is not None
        assert ch.ranks() == sorted(ranks)


def test_non_cartesian_rejected():
    assert ranks_to_channel([0, 1, 3]) is None
    assert ranks_to_channel([0, 1, 2, 4]) is None
    assert ranks_to_channel([0, 1, 4, 6]) is None


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=37),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=1, max_value=6))
    @settings(max_examples=80, deadline=None)
    def test_factorization_roundtrip_random_strided(offset, stride, size):
        ranks = [offset + i * stride for i in range(size)]
        ch = ranks_to_channel(ranks)
        assert ch is not None
        assert ch.ranks() == ranks
        assert ch.size == size
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_factorization_roundtrip_random_strided():
        pass


def test_hash_offset_independent():
    a = ranks_to_channel([0, 1, 2, 3])
    b = ranks_to_channel([8, 9, 10, 11])
    c = ranks_to_channel([0, 2, 4, 6])
    assert a.hash_id == b.hash_id
    assert a.hash_id != c.hash_id


def test_aggregate_closure_2d_grid():
    """Row + column channels of a 4x4 grid combine to cover the world."""
    reg = ChannelRegistry(16)
    row = reg.register_ranks([0, 1, 2, 3])          # stride 1, size 4
    col = reg.register_ranks([0, 4, 8, 12])         # stride 4, size 4
    assert reg.covers_world({row.hash_id, col.hash_id})
    assert not reg.covers_world({row.hash_id})
    assert not reg.covers_world({col.hash_id})


def test_aggregate_closure_3d_grid():
    reg = ChannelRegistry(64)
    x = reg.register_ranks([0, 1, 2, 3])            # stride 1
    y = reg.register_ranks([0, 4, 8, 12])           # stride 4
    z = reg.register_ranks([0, 16, 32, 48])         # stride 16
    assert not reg.covers_world({x.hash_id, y.hash_id})
    assert reg.covers_world({x.hash_id, y.hash_id, z.hash_id})
    # a slice (xy-plane) + the z fiber also covers
    plane = reg.register_ranks(list(range(16)))
    assert reg.covers_world({plane.hash_id, z.hash_id})


def test_incompatible_channels_do_not_cover():
    reg = ChannelRegistry(16)
    a = reg.register_ranks([0, 1, 2, 3])
    b = reg.register_ranks([0, 2, 4, 6])   # overlapping strides: not disjoint
    assert not reg.covers_world({a.hash_id, b.hash_id})
