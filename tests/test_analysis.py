"""launch analysis layers: jaxpr cost counter, trip-aware HLO walker,
collective byte accounting, cpu-upcast parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh

from repro.launch.hlo_analysis import (collective_stats, group_size,
                                       parse_collective_line)
from repro.launch.hlo_graph import (collective_stats_trip_aware,
                                    while_census)
from repro.launch.jaxpr_cost import cost_of

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8,
                                reason="needs 8 virtual devices")


def _mesh():
    return make_mesh((8,), ("model",), axis_types=(AxisType.Auto,))


def test_jaxpr_cost_exact_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = cost_of(lambda a, b: a @ b, a, b)
    assert c.dot_flops == 2 * 64 * 128 * 32


def test_jaxpr_cost_scan_multiplies():
    W = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def f(ws, x):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]
    c = cost_of(f, W, x)
    assert c.dot_flops == 10 * 2 * 4 * 64 * 64


def test_jaxpr_cost_counts_remat():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        g = jax.checkpoint(lambda y: jnp.sum((y @ y) ** 2))
        return jax.grad(g)(x)
    base = cost_of(lambda x: jax.grad(
        lambda y: jnp.sum((y @ y) ** 2))(x), x)
    rem = cost_of(f, x)
    assert rem.dot_flops >= base.dot_flops    # recompute visible


def test_trip_aware_collectives():
    mesh = _mesh()
    W = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def f(ws, x):
        def body(h, w):
            y = h @ w
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, "model")))
            h2 = y @ w.T
            h2 = jax.lax.with_sharding_constraint(
                h2, NamedSharding(mesh, P()))
            return h2, None
        return jax.lax.scan(body, x, ws)[0]

    comp = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P(None, None, "model")),
        NamedSharding(mesh, P()))).lower(W, x).compile()
    hlo = comp.as_text()
    flat = collective_stats(hlo)
    aware = collective_stats_trip_aware(hlo)
    assert flat.count_by_kind.get("all-reduce") == 1
    assert aware.count_by_kind.get("all-reduce") == 10
    assert aware.bytes_by_kind["all-reduce"] == \
        10 * flat.bytes_by_kind["all-reduce"]
    trips = dict(while_census(hlo))
    assert 10 in trips.values()


def test_group_size_parsing():
    assert group_size("replica_groups=[16,32]<=[512]") == 32
    assert group_size("replica_groups={{0,4},{1,5}}") == 2
    assert group_size("no groups here") == 1


def test_parse_collective_conversions():
    line = ("%all-gather.1 = bf16[32,128]{1,0} all-gather(%x), "
            "replica_groups=[2,16]<=[32], dimensions={0}")
    base, nbytes = parse_collective_line(line)
    assert base == "all-gather"
    assert nbytes == 32 * 128 * 2 // 16       # result / group size
    line2 = ("%reduce-scatter.3 = f32[8,16]{1,0} reduce-scatter(%y), "
             "replica_groups=[1,4]<=[4], dimensions={0}")
    base2, nbytes2 = parse_collective_line(line2)
    assert base2 == "reduce-scatter"
    assert nbytes2 == 8 * 16 * 4 * 4          # result * group size
