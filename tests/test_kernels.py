"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, matmul, rmsnorm
from repro.kernels import ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 512, 384),
                                 (64, 96, 32), (8, 8, 8), (512, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_allclose(mkn, dtype):
    M, K, N = mkn
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (M, K), dtype)
    b = jax.random.normal(k2, (K, N), dtype)
    got = np.asarray(matmul(a, b), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("shape", [(4, 64, 128), (3, 37, 96), (1, 1, 8),
                                   (2, 200, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_allclose(shape, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, dtype)
    w = (jax.random.normal(k2, shape[-1:]) * 0.1).astype(dtype)
    got = np.asarray(rmsnorm(x, w), np.float32)
    want = np.asarray(ref.rmsnorm_ref(x, w), np.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize(
    "dims", [(2, 128, 128, 4, 2, 64),     # square causal GQA
             (1, 64, 256, 8, 8, 32),      # suffix queries (Sq < Skv)
             (2, 256, 256, 6, 2, 64),     # multi-tile both ways
             (1, 96, 96, 3, 1, 16)])      # MQA, non-128 sizes
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_allclose(dims, dtype):
    B, Sq, Skv, H, KVH, d = dims
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Sq, H, d), dtype)
    k = jax.random.normal(k2, (B, Skv, KVH, d), dtype)
    v = jax.random.normal(k3, (B, Skv, KVH, d), dtype)
    got = np.asarray(flash_attention(q, k, v, causal=True), np.float32)
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=True),
                      np.float32)
    tol = 4e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_flash_attention_matches_model_reference_path():
    """The kernel and the model's chunked_attention agree (same math)."""
    from repro.models.layers import chunked_attention
    B, S, H, KVH, d = 2, 64, 4, 2, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, H, d))
    k = jax.random.normal(k2, (B, S, KVH, d))
    v = jax.random.normal(k3, (B, S, KVH, d))
    pos = jnp.arange(S)
    a = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True, kv_chunk=16)
    b = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=3e-4)
