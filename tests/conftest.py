"""Test configuration.

The suite includes multi-device tests (sharding, shard_map collectives,
elastic resharding), so the host platform is split into 8 virtual devices —
deliberately 8, NOT the dry-run's 512 (production lowering is exercised
only through launch/dryrun.py, which sets its own flag).  Must run before
jax initializes a backend.
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
