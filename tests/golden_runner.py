"""Deterministic golden-report harness for the engine refactor.

Runs the exhaustive-autotune protocol over tiny versions of the three
op-mix-distinct case studies (SLATE Cholesky: nonblocking p2p; Capital:
sub-communicator collectives; CANDMC: blocking p2p + collectives) under all
five selective-execution policies, with a FULLY DETERMINISTIC cost model
(``bias_sigma=0`` removes the allocation-bias term; since PR 2 the bias
itself is also process-stable — crc32, not ``hash()`` — so even
bias_sigma>0 studies reproduce across processes and checkpoint resumes).

``compute_goldens()`` returns a nested dict of every ConfigRecord field.
``python -m tests.golden_runner`` (from the repo root, with PYTHONPATH=src)
regenerates ``tests/golden_reports.json``; the committed file was produced
by the PRE-refactor seed engine, so ``tests/test_golden_reports.py`` pins
the optimized engine to bit-identical protocol output.
"""

from __future__ import annotations

import json
import os

from repro.core.policies import POLICIES, policy
from repro.core.tuner import Autotuner, Configuration, Study
from repro.linalg import candmc_qr, capital_cholesky, slate_cholesky
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden_reports.json")


def _studies():
    slate = Study(
        name="golden-slate", world_size=16, reset_between_configs=True,
        configs=[
            Configuration(
                name="slate-t64-la1", params={},
                make_program=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=64, lookahead=1, pr=4, pc=4)),
            Configuration(
                name="slate-t128-la0", params={},
                make_program=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=128, lookahead=0, pr=4, pc=4)),
        ])
    capital = Study(
        name="golden-capital", world_size=8, reset_between_configs=False,
        configs=[
            Configuration(
                name="capital-b32-s1", params={},
                make_program=lambda w: capital_cholesky.make_program(
                    w, n=256, block=32, strategy=1, grid_c=2)),
            Configuration(
                name="capital-b64-s2", params={},
                make_program=lambda w: capital_cholesky.make_program(
                    w, n=256, block=64, strategy=2, grid_c=2)),
        ])
    candmc = Study(
        name="golden-candmc", world_size=16, reset_between_configs=True,
        configs=[
            Configuration(
                name="candmc-b16-g4x4", params={},
                make_program=lambda w: candmc_qr.make_program(
                    w, m=1024, n=128, block=16, pr=4, pc=4)),
        ])
    return (slate, capital, candmc)


def golden_space(index: int = 1):
    """Session-API space of one tiny golden study — also the remote-worker
    spec used by the scheduler smoke tests and ``check.sh --stage
    scheduler``: ``python -m repro.api.worker --spec
    golden_runner:golden_space --spec-args '{"index": 1}'`` (with tests/
    on PYTHONPATH)."""
    from repro.core.tuner import space_of_study
    return space_of_study(_studies()[index])


def compute_goldens() -> dict:
    out = {}
    for study in _studies():
        srec = {}
        for pol in POLICIES:
            cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                           bias_sigma=0.0)
            tuner = Autotuner(study, policy(pol, tolerance=0.25), trials=2,
                              seed=0, timer=cm.sample)
            rep = tuner.tune()
            srec[pol] = [
                {"name": r.name, "full_time": r.full_time,
                 "predicted": r.predicted, "rel_error": r.rel_error,
                 "comp_error": r.comp_error,
                 "selective_cost": r.selective_cost,
                 "full_cost": r.full_cost, "executed": r.executed,
                 "skipped": r.skipped, "predictions": r.predictions}
                for r in rep.records]
        out[study.name] = srec
    return out


def main():
    goldens = compute_goldens()
    with open(GOLDEN_PATH, "w") as f:
        json.dump(goldens, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
