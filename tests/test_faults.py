"""Chaos-harness tests: ``repro.api.faults`` + fleet fault tolerance.

- ``FaultPlan``: JSON roundtrip, counter-deterministic schedule, marker
  files arming lethal faults exactly once across restarts;
- ``FaultInjector``: targeted and seeded executor-level sabotage — a
  killed task is retried and the merged sweep is bit-identical to the
  serial driver (the chaos acceptance property), with the recovery
  provenance surfaced in ``StudyResult.extra``;
- ``on_failure="skip"``: a persistently-failing sweep point exhausts its
  retries, the rest of the grid completes, the failure (with attempt
  history) and every recovery event land in the checkpoint, and a
  resumed sweep re-attempts exactly the failed point;
- ``_Checkpoint`` crash safety: a failed flush leaves the journal intact
  and no stray temp files;
- the full acceptance smoke: a supervised 2-worker fleet where chaos
  kills one worker mid-task — the supervisor restarts it, it rejoins the
  listening executor, and the sweep finishes bit-identical to serial.
"""

import os

import pytest

from repro.api import (AutotuneSession, FaultInjector, FaultPlan,
                       RemoteExecutor, SimBackend, WorkerPool, WorkerSpec)
from repro.api.scheduler import InProcessExecutor
from repro.api.session import _Checkpoint

from golden_runner import golden_space

KW = dict(policies=["conditional", "eager"], tolerances=[0.25])


def _sess(backend=None):
    return AutotuneSession(golden_space(1),
                           backend=backend or SimBackend(), trials=2)


def _strip(result) -> dict:
    d = result.to_json()
    d.pop("wall_s", None)
    d.get("extra", {}).pop("recovery", None)
    # remote workers default to a program cache; the serial reference does
    # not — replay is bit-identical, only the provenance counters differ
    d.get("extra", {}).pop("program_cache", None)
    return d


def _env() -> dict:
    here = os.path.dirname(__file__)
    src = os.path.abspath(os.path.join(here, os.pardir, "src"))
    return {"PYTHONPATH": os.pathsep.join(
        [src, here] + os.environ.get("PYTHONPATH", "").split(os.pathsep))}


# -- FaultPlan -----------------------------------------------------------------

def test_fault_plan_roundtrip_and_marker(tmp_path):
    marker = str(tmp_path / "fired")
    plan = FaultPlan(kill_after=2, delay_s=0.01, marker=marker)
    assert FaultPlan.from_json(plan.to_json()) == plan

    # the marker arms lethal faults exactly once: a supervisor-restarted
    # worker finds it and runs clean
    armed = FaultPlan(hang_after=1, hang_s=0.0, marker=marker)
    assert armed._armed()
    armed.before_task()
    assert os.path.exists(marker)
    restarted = FaultPlan(hang_after=1, hang_s=0.0, marker=marker)
    assert not restarted._armed()


def test_fault_plan_reply_schedule():
    p = FaultPlan(drop_after=1, corrupt_after=2)
    p.before_task()
    assert p.transform_reply(b'{"ok": 1}\n') is None       # dropped
    p.before_task()
    corrupted = p.transform_reply(b'{"ok": 2}\n')
    with pytest.raises(ValueError):
        __import__("json").loads(corrupted)                # really garbage
    p.before_task()
    assert p.transform_reply(b'{"ok": 3}\n') == b'{"ok": 3}\n'


# -- FaultInjector -------------------------------------------------------------

def test_injected_kill_is_retried_bit_identical():
    serial = [_strip(r) for r in _sess().sweep(workers=1, **KW)]
    ex = FaultInjector(InProcessExecutor(), kill_tasks=[0])
    sess = _sess()
    chaotic = sess.sweep(executor=ex, max_retries=2, **KW)
    assert [_strip(r) for r in chaotic] == serial
    # the kill left provenance: one retry, chaos named as the worker
    rec = chaotic[0].extra["recovery"]
    assert rec["retries"] == 1
    assert rec["attempts"][0]["worker"] == "chaos"
    assert ex.log == [{"task": 0, "fate": "kill"}]
    names = {e["event"] for e in sess.last_sweep_events}
    assert "chaos_kill" in names and "task_retry" in names
    # the clean point carries no recovery entry
    assert "recovery" not in chaotic[1].extra


def test_seeded_chaos_sweep_completes_under_retries():
    serial = [_strip(r) for r in _sess().sweep(workers=1, **KW)]
    ex = FaultInjector(InProcessExecutor(), seed=7, kill_prob=0.4,
                       corrupt_prob=0.3, max_faults=3)
    got = _sess().sweep(executor=ex, max_retries=5, **KW)
    assert [_strip(r) for r in got] == serial
    assert len(ex.log) <= 3                 # the fault budget bounds chaos


# -- skip / checkpoint / resume ------------------------------------------------

class _CursedTol(SimBackend):
    """Persistently fails every attempt at one grid point: retries
    cannot save it, only ``on_failure="skip"`` can save the sweep."""

    def __init__(self, bad_tol, **kw):
        super().__init__(**kw)
        self.bad_tol = bad_tol

    def open(self, space, policy, **kw):
        if policy.tolerance == self.bad_tol:
            raise RuntimeError(f"tolerance {policy.tolerance} is cursed")
        return super().open(space, policy, **kw)


def test_skip_journals_failure_and_resume_completes(tmp_path):
    ck = str(tmp_path / "ck.json")
    kw = dict(policies=["eager"], tolerances=[1.0, 0.25, 0.0625])
    got = _sess(_CursedTol(0.25)).sweep(workers=1, checkpoint=ck,
                                        max_retries=1, on_failure="skip",
                                        **kw)
    # partial results: the cursed slot is None, the rest completed
    assert got[1] is None
    assert got[0] is not None and got[2] is not None

    journal = _Checkpoint(ck)
    fail, = journal._data["failures"].values()
    assert len(fail["attempts"]) == 2       # first try + one retry
    assert "cursed" in fail["attempts"][0]["error"]
    assert any(e["event"] == "task_retry" for e in journal.events())
    assert any(e["event"] == "task_failed" for e in journal.events())

    # resume with a healthy backend: exactly the failed point re-runs
    resumed = _sess().sweep(workers=1, checkpoint=ck, **kw)
    ref = _sess().sweep(workers=1, **kw)
    assert [_strip(r) for r in resumed] == [_strip(r) for r in ref]
    # the completed re-attempt superseded the journaled failure
    assert not _Checkpoint(ck)._data.get("failures")


def test_skip_without_checkpoint_returns_partial(tmp_path):
    got = _sess(_CursedTol(0.25)).sweep(
        workers=1, max_retries=0, on_failure="skip",
        policies=["eager"], tolerances=[1.0, 0.25])
    assert got[1] is None and got[0] is not None


def test_checkpoint_flush_is_crash_safe(tmp_path):
    path = str(tmp_path / "ck.json")
    ck = _Checkpoint(path)
    ck.add_event({"event": "probe"})
    before = open(path).read()
    # poison the journal: the flush fails mid-serialize, but the file on
    # disk must stay the last good journal, with no temp debris
    ck._data["poison"] = object()
    with pytest.raises(TypeError):
        ck.add_event({"event": "second"})
    assert open(path).read() == before
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []
    assert _Checkpoint(path).events() == [{"event": "probe"}]


def test_wedged_worker_task_reassigned_live(tmp_path):
    """A real worker wedged by ``FaultPlan(hang_after=1)`` trips the task
    deadline; the task reassigns to the healthy worker and the sweep
    stays bit-identical.  Also pins that an *idle* connect-mode worker
    survives multi-second gaps between tasks (the healthy worker sits
    idle for the whole 3s deadline; a leftover dial timeout on its socket
    used to kill it exactly here)."""
    space = golden_space(1)
    serial = [_strip(r) for r in _sess().sweep(workers=1, **KW)]

    ex = RemoteExecutor(listen="127.0.0.1:0", join_timeout=60,
                        task_timeout=3.0, expect={"space": space.name})
    marker = str(tmp_path / "hang.marker")
    spec = dict(spec="golden_runner:golden_space", spec_args={"index": 1},
                connect=ex.listen_address, env=_env())
    specs = [WorkerSpec(faults={"hang_after": 1, "marker": marker},
                        **spec),
             WorkerSpec(**spec)]
    sess = _sess()
    with WorkerPool(specs, restart_backoff=0.1):
        got = sess.sweep(executor=ex, max_retries=3, **KW)
    assert [_strip(r) for r in got] == serial
    names = {e["event"] for e in sess.last_sweep_events}
    assert "task_deadline" in names and "task_retry" in names


# -- the acceptance smoke: kill, restart, rejoin, finish -----------------------

def test_chaos_kill_supervised_fleet_completes_bit_identical(tmp_path):
    """Chaos kills 1 of 2 workers mid-task; the supervisor restarts it,
    it rejoins the listening executor, the killed task is retried, and
    the sweep lands bit-identical to the serial driver."""
    space = golden_space(1)
    serial = [_strip(r) for r in _sess().sweep(workers=1, **KW)]

    ex = RemoteExecutor(listen="127.0.0.1:0", join_timeout=60,
                        task_timeout=120, expect={"space": space.name})
    marker = str(tmp_path / "kill.marker")
    spec = dict(spec="golden_runner:golden_space",
                spec_args={"index": 1}, connect=ex.listen_address,
                env=_env())
    specs = [WorkerSpec(faults={"kill_after": 1, "marker": marker},
                        **spec),
             WorkerSpec(**spec)]
    sess = _sess()
    with WorkerPool(specs, restart_backoff=0.1) as pool:
        got = sess.sweep(executor=ex, max_retries=3, **KW)
        assert [_strip(r) for r in got] == serial
        assert os.path.exists(marker)       # the kill really fired
        recoveries = [r.extra["recovery"] for r in got
                      if "recovery" in r.extra]
        assert recoveries and recoveries[0]["retries"] >= 1
        assert pool.restarts() >= 1         # supervisor brought it back
    assert any(e["event"] == "worker_restart" for e in pool.events)
    names = {e["event"] for e in sess.last_sweep_events}
    assert "worker_joined" in names         # elastic join happened
    assert "worker_lost" in names           # the kill was observed
    assert "task_retry" in names            # and recovered from
