"""Compiled warm program: bit-identity against the scalar engine.

PR 9 lowers the recorded event program into compiled segments: maximal
per-rank runs of computation events between skip-decision and
communication boundaries become head entries that batch-charge the whole
segment when every kernel in it is in the memoized-skip regime, and the
straggler-enabled cost model adopts a counter-based (Philox-style) RNG
discipline so mixed normal/uniform draws batch per segment.  These tests
pin the compiled path (``compiled=True``, the default for trace-cached
selective runs) to the scalar event-program interpreter
(``compiled=False``) and the seed-style live engine
(``trace_cache=False``), requiring bit-identical reports, engine state
and RNG streams — plus segment-boundary edge cases the SLATE/Capital/
CANDMC studies don't produce on their own (comm-only programs, segments
of a single event, skip decisions flipping mid-program).

The full 5-policies x 3-studies x straggler matrix already runs the
compiled path implicitly in tests/test_cold_path.py (compiled is the
default); here the matrix is compiled-vs-scalar-interpreter, which
isolates the warm-program lowering from the recording pass.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.critter import Critter, W_BHEAD, W_CHEAD
from repro.core.policies import POLICIES, policy
from repro.core.signatures import Signature
from repro.linalg import slate_cholesky
from repro.simmpi import Comp, Coll, Isend, Recv
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.runtime import Runtime

REPORT_FIELDS = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                 "measured_time", "max_measured_comp", "executed",
                 "skipped", "events")


def _slate(w):
    return slate_cholesky.make_program(w, n=512, tile=64, lookahead=1,
                                       pr=4, pc=4)


def _state_snapshot(critter):
    S = critter.state
    return (S.mean_arr.tobytes(), S.freq.tobytes(), S.seen.tobytes(),
            S.skip_ok.tobytes(), S.iter_exec.tobytes(), S.clock.tobytes(),
            S.path_exec.tobytes(), S.path_comm.tobytes(),
            S.goff.tobytes(), S.gmean.tobytes(),
            sorted(critter.global_off),
            sorted((r, sid, st.n, st.mean, st.m2, st.total, st.min_t,
                    st.max_t)
                   for r in range(S.n_ranks)
                   for sid, st in S.kbar[r].items()))


def _trace(make, world_size, pol, *, straggler_p=0.0, compiled=True,
           trace_cache=True, counter_rng=False, iters=3, timer=None):
    """Forced run + ``iters`` selective iterations; per-iteration reports
    and state fingerprints plus the final RNG stream position."""
    w = World(world_size)
    c = Critter(w, policy(pol, tolerance=0.25))
    if timer is None:
        cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                       straggler_p=straggler_p, counter_rng=counter_rng)
        sample = cm.sample
    else:
        cm = None
        sample = timer
    rt = Runtime(w, c, sample, seed=3, trace_cache=trace_cache,
                 compiled=compiled)
    prog = make(w)
    out = []
    for i in range(1 + iters):
        res = rt.run(prog, force_execute=(i == 0))
        out.append(tuple(getattr(res, f) for f in REPORT_FIELDS))
        out.append(_state_snapshot(c))
    out.append(cm.draw_index if counter_rng else
               rt._rng.bit_generator.state)
    return out, rt, prog


def _assert_traces_equal(a, b, label):
    for i, (x, y) in enumerate(zip(a, b)):
        assert x == y, f"{label}: divergence at trace step {i}"


# ------------------------------------------------- compiled vs interpreter

@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("straggler_p", [0.002, 0.0],
                         ids=["straggler-on", "straggler-off"])
def test_compiled_matches_scalar_interpreter(pol, straggler_p):
    comp, _, _ = _trace(_slate, 16, pol, straggler_p=straggler_p,
                        compiled=True)
    scal, _, _ = _trace(_slate, 16, pol, straggler_p=straggler_p,
                        compiled=False)
    _assert_traces_equal(comp, scal, f"{pol}/straggler={straggler_p}")


def test_compiled_is_the_default_selective_path():
    """``compiled=True`` (the default) must actually build and run the
    warm program on selective iterations; ``compiled=False`` must not."""
    _, rt_c, prog_c = _trace(_slate, 16, "online", compiled=True)
    _, rt_s, prog_s = _trace(_slate, 16, "online", compiled=False)
    assert rt_c._traces[prog_c].warm is not None
    assert rt_s._traces[prog_s].warm is None


# ------------------------------------------------- segment-boundary edges

def _comm_only(w):
    wc = w.world_comm

    def program(rank, world):
        for _ in range(4):
            yield Coll("allreduce", wc, 4096)
            yield Coll("bcast", wc, 8192)
    return program


def test_comm_only_program_has_no_segments():
    """A program with no computation never opens a comp run: the warm
    program degenerates to per-event entries (zero segments) and still
    matches the scalar interpreter bit-for-bit."""
    comp, rt, prog = _trace(_comm_only, 8, "online", compiled=True)
    scal, _, _ = _trace(_comm_only, 8, "online", compiled=False)
    _assert_traces_equal(comp, scal, "comm-only")
    meta = rt.warm_meta(prog)
    assert meta["segments"] == 0 and meta["fused_events"] == 0
    assert meta["coll_entries"] == 8


def _single_event_segments(w):
    wc = w.world_comm

    def program(rank, world):
        for i in range(6):
            yield Comp("gemm", (64, 64, 64))      # lone comp: run of 1
            yield Coll("barrier", wc, 0)
    return program


def test_single_event_segments_never_fuse():
    """A comp run of one event gets no head entry (nothing to batch), so
    the warm program carries it as a plain W_COMP — and the charge is
    identical either way."""
    comp, rt, prog = _trace(_single_event_segments, 4, "online",
                            compiled=True)
    scal, _, _ = _trace(_single_event_segments, 4, "online",
                        compiled=False)
    _assert_traces_equal(comp, scal, "single-event segments")
    meta = rt.warm_meta(prog)
    assert meta["segments"] == 0 and meta["fused_events"] == 0
    assert meta["comp_entries"] == 24                  # 6 comps x 4 ranks
    warm = rt._traces[prog].warm
    heads = [e for e in warm.entries if e[0] in (W_CHEAD, W_BHEAD)]
    assert heads == []


def _flip_prone(w):
    wc = w.world_comm

    def program(rank, world):
        for i in range(8):
            # segment of 3: two stable kernels plus one noisy one whose
            # confidence interval never tightens below tolerance, so the
            # segment's skip guard fails and the compiled path must fall
            # back to per-event processing at the original positions
            # (the trailing float is the explicit-flops convention)
            yield Comp("gemm", (64, 64, 64))
            yield Comp("noisy", (8, 1e6))
            yield Comp("trsm", (64, 64))
            yield Coll("allreduce", wc, 1024)
    return program


def test_skip_decision_flips_mid_program():
    """Mixed skip/execute inside one segment: the noisy kernel stays
    unpredictable while its neighbours reach the skip regime, so the
    segment guard fails every iteration and charges event-by-event — in
    recorded order, drawing the exact RNG stream of the scalar engine."""
    def noisy_timer(sig, rng):
        if sig.kind == "comp" and sig.name == "noisy":
            return 1e-3 * (0.5 + rng.random() * 4.0)   # ~3x swings
        if sig.kind == "comp":
            return 1e-3 * (1.0 + 0.01 * rng.normal())
        return 1e-4

    comp, rt, prog = _trace(_flip_prone, 4, "online", compiled=True,
                            iters=5, timer=noisy_timer)
    scal, _, _ = _trace(_flip_prone, 4, "online", compiled=False,
                        iters=5, timer=noisy_timer)
    live, _, _ = _trace(_flip_prone, 4, "online", trace_cache=False,
                        iters=5, timer=noisy_timer)
    _assert_traces_equal(comp, scal, "flip-prone vs interpreter")
    _assert_traces_equal(comp, live, "flip-prone vs live")
    meta = rt.warm_meta(prog)
    assert meta["segments"] > 0                        # fusion did happen
    # the last selective iteration really did mix skips and executions
    final = comp[-3]
    assert 0 < final[6] < final[8], (
        f"expected mixed skip/execute, got {final[6]}/{final[8]}")


def test_eager_aggregation_inside_segments():
    """The eager policy re-aggregates global statistics at collectives —
    mid-replay, between segments.  The compiled path must observe the
    refreshed global skip set exactly as the scalar engine does."""
    comp, _, _ = _trace(_flip_prone, 4, "eager", compiled=True, iters=5)
    scal, _, _ = _trace(_flip_prone, 4, "eager", compiled=False, iters=5)
    live, _, _ = _trace(_flip_prone, 4, "eager", trace_cache=False,
                        iters=5)
    _assert_traces_equal(comp, scal, "eager vs interpreter")
    _assert_traces_equal(comp, live, "eager vs live")


# ------------------------------------------------------- counter-RNG path

def test_counter_scalar_vs_block_bit_identical():
    sigs = [Signature("comp", "gemm", (128, 128, 128)),
            Signature("comp", "potrf", (128,)),
            Signature("comm", "bcast", (65536, 8, 1))] * 30
    a = CostModel(KNL_STAMPEDE2, allocation=0, seed=11, straggler_p=0.05,
                  counter_rng=True)
    b = CostModel(KNL_STAMPEDE2, allocation=0, seed=11, straggler_p=0.05,
                  counter_rng=True)
    rng = np.random.default_rng(0)
    scalar = [a.sample(s, rng) for s in sigs]
    block = b.sample_block(sigs)
    assert block is not None
    assert scalar == block.tolist()
    assert a.draw_index == b.draw_index == 3 * len(sigs)
    # the host Generator is never touched in counter mode
    assert rng.bit_generator.state == \
        np.random.default_rng(0).bit_generator.state


def test_counter_mode_disables_legacy_batching():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, counter_rng=True)
    assert cm.batch_info([Signature("comp", "gemm", (64, 64, 64))]) is None
    legacy = CostModel(KNL_STAMPEDE2, allocation=0, seed=0)
    assert legacy.sample_block(
        [Signature("comp", "gemm", (64, 64, 64))]) is None


@pytest.mark.parametrize("pol", ["online", "eager"])
def test_counter_rng_cold_and_warm_bit_identical(pol):
    """With stragglers ON and counter mode, the batched cold path and the
    compiled warm path must match the live engine — including the draw
    cursor, the counter-mode analogue of the bit-generator state (this is
    the PR-5 residual: the straggler cold path used to fall back to
    per-event scalar draws; now it batches through sample_block)."""
    cached, _, _ = _trace(_slate, 16, pol, straggler_p=0.002,
                          counter_rng=True, trace_cache=True)
    live, _, _ = _trace(_slate, 16, pol, straggler_p=0.002,
                        counter_rng=True, trace_cache=False)
    _assert_traces_equal(cached, live, f"counter/{pol}")
    assert cached[-1] == live[-1] > 0       # draw cursors advanced, equal


def test_counter_cold_cursor_matches_live():
    """The recording (forced) run in counter mode pre-draws through
    sample_block — one bulk cursor advance that must land exactly where
    the per-event live pass leaves its cursor (3 counter slots per drawn
    sample, whether the straggler branch fires or not)."""
    cursors = []
    for trace_cache in (True, False):
        w = World(16)
        c = Critter(w, policy("online", tolerance=0.25))
        cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                       straggler_p=0.002, counter_rng=True)
        rt = Runtime(w, c, cm.sample, seed=3, trace_cache=trace_cache)
        rt.run(_slate(w), force_execute=True)
        cursors.append(cm.draw_index)
    assert cursors[0] == cursors[1] > 0
    assert cursors[0] % 3 == 0


# ----------------------------------------------------------- meta sanity

def test_warm_meta_sanity():
    _, rt, prog = _trace(_slate, 16, "online")
    meta = rt.warm_meta(prog)
    assert meta["segments"] > 0
    assert meta["fused_events"] >= 2 * meta["segments"]  # heads fuse >= 2
    assert 2.0 <= meta["mean_batch"] <= meta["max_batch"]
    warm = rt._traces[prog].warm
    assert meta["entries"] == len(warm.entries)
    heads = sum(1 for e in warm.entries if e[0] in (W_CHEAD, W_BHEAD))
    assert heads == meta["segments"]
    # entry-kind counters tally the pre-segmentation entry stream
    assert (meta["comp_entries"] + meta["block_entries"]
            + meta["coll_entries"] + meta["p2p_entries"]
            + meta["ipost_entries"] + meta["imatch_entries"]
            ) == meta["entries"]


def test_bench_engine_verify_wiring():
    """The check.sh engine stage's in-process gates."""
    from benchmarks.bench_engine import (verify_compiled_path,
                                         verify_counter_rng)
    summary = verify_compiled_path(16)
    assert summary["configs"] == 4
    assert summary["compiled"]["segments"] > 0
    summary = verify_counter_rng(16)
    assert summary["draws"] > 0
