"""Search-driver contract tests, centered on ``model_guided``.

- golden regression: the model-guided driver on the Capital ci grid lands
  the committed exhaustive winner (benchmarks/results/transfer.json,
  PR-3's artifact of the PR-2 protocol) while executing <10% of the grid;
- bit-identity: serial == fork-pool == resumed-from-checkpoint (the
  sampler RNG is carried like the sim RNG), and ``sweep(driver=...)``
  equals a session constructed with that search;
- the roofline prefilter never dispatches a candidate whose analytic
  lower bound exceeds the incumbent's upper CI, passes everything with no
  incumbent, and the prune is visible in the sweep's task journal;
- degenerate models fall back to uniform candidate sampling;
- ``SearchSpace`` enumeration order is pinned: construction order,
  unique names, and a process-stable ``order_fingerprint`` that
  model-guided resume validates.
"""

import json
import math
import os
import tempfile

import pytest

from repro.api import (AutotuneSession, BackendRun, ConfigPoint,
                       Measurement, SearchSpace, SimBackend,
                       StatisticsBank, model_guided)
from repro.api.scheduler import fork_available
from repro.core.policies import policy as make_policy
from repro.linalg.studies import search_space

_RESULTS = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "results")
BANK_PATH = os.path.join(_RESULTS, "capital-cholesky-ci_stats_bank.json")
TRANSFER_PATH = os.path.join(_RESULTS, "transfer.json")


def _capital_space():
    return search_space("capital-cholesky", scale="ci")


def _bank():
    return StatisticsBank.load(BANK_PATH)


def _exhaustive_winner() -> str:
    """The committed exhaustive (paper-protocol) winner on Capital ci."""
    with open(TRANSFER_PATH) as f:
        rows = json.load(f)
    cold = next(r for r in rows if r["run"] == "cold")
    return cold["chosen"]


def _session(space, backend=None, **kw):
    kw.setdefault("search", "model_guided")
    kw.setdefault("search_options", {"banks": [_bank()], "seed": 0})
    return AutotuneSession(space, backend=backend or SimBackend(),
                           policy="eager", tolerance=0.25, trials=2, **kw)


def _strip(result) -> dict:
    d = result.to_json()
    d.pop("wall_s", None)
    return d


# -- golden regression: same winner, <10% of the grid -------------------------

def test_model_guided_capital_ci_matches_exhaustive_winner():
    result = _session(_capital_space()).run()
    assert result.extra["fallback"] is None        # the model guided
    assert result.extra["coverage"] < 0.10
    winner = _exhaustive_winner()
    assert result.extra["best"] == winner
    assert result.chosen.name == winner
    # unvisited points carry shape-uniform, unmeasured records
    visited = set(result.extra["dispatched"])
    for rec in result.records:
        if rec.name not in visited:
            assert rec.predictions == [] and rec.executed == 0
            assert math.isinf(rec.predicted)


# -- bit-identity: serial == fork == resumed ----------------------------------

def test_model_guided_serial_equals_fork_and_driver_override():
    space = _capital_space()
    kw = dict(policies=["eager"], tolerances=[0.25, 0.125])
    serial = [_strip(r) for r in _session(space).sweep(workers=1, **kw)]
    if fork_available():
        forked = [_strip(r) for r in _session(space).sweep(workers=2,
                                                           **kw)]
        assert forked == serial
    # sweep(driver=...) on an exhaustive-configured session is the same
    # sweep — and leaves the session's own search untouched
    sess = _session(space, search="exhaustive")
    over = [_strip(r) for r in sess.sweep(driver="model_guided",
                                          workers=1, **kw)]
    assert over == serial
    assert sess.search == "exhaustive"


class _FailingBackend(SimBackend):
    """Raises on the Nth selective trial (None = never); counts profile
    calls so resume can prove it skipped re-selection."""

    def __init__(self, fail_after, **kw):
        super().__init__(**kw)
        self.fail_after = fail_after
        self.profile_calls = 0

    def open(self, *a, **kw):
        run = super().open(*a, **kw)
        orig_trial, seen = run.run_trial, [0]

        def trial(point):
            seen[0] += 1
            if self.fail_after is not None and seen[0] > self.fail_after:
                raise RuntimeError("injected mid-racing failure")
            return orig_trial(point)

        orig_profile = run.kernel_profile

        def profile(point):
            self.profile_calls += 1
            return orig_profile(point)

        run.run_trial = trial
        run.kernel_profile = profile
        return run


def test_model_guided_resume_is_bit_identical():
    space = _capital_space()
    opts = {"banks": [_bank()], "seed": 0, "top_k": 4}
    ref = _session(space, search_options=opts).run()
    assert len(ref.extra["dispatched"]) == 4

    ck = os.path.join(tempfile.mkdtemp(prefix="repro-search-"), "ck.json")
    with pytest.raises(RuntimeError, match="injected"):
        _session(space, backend=_FailingBackend(2),
                 search_options=opts).run(checkpoint=ck)
    with open(ck) as f:
        data = json.load(f)
    # the candidate selection (survivors + sampler RNG + space order) was
    # journaled before racing started
    (state,) = data["search_state"].values()
    # survivors are journaled in ranked order; dispatch re-sorts to
    # space enumeration order
    assert sorted(state["survivors"]) == sorted(ref.extra["dispatched"])
    assert state["space_order"] == space.order_fingerprint()
    assert state["rng"]["bit_generator"] == "PCG64"

    resumed_backend = _FailingBackend(None)
    resumed = _session(space, backend=resumed_backend,
                       search_options=opts).run(checkpoint=ck)
    assert _strip(resumed) == _strip(ref)
    # resume replayed the journaled selection: no re-profiling, no
    # re-consumed sampler draws
    assert resumed_backend.profile_calls == 0
    with open(ck) as f:
        data = json.load(f)
    assert data["search_state"] == {}       # cleared by the final result
    assert len(data["results"]) == 1


def test_model_guided_resume_rejects_reordered_space():
    space = _capital_space()
    run = SimBackend().open(space, make_policy("eager", tolerance=0.25))
    stale = {"space_order": "order:deadbeef:15", "survivors": [],
             "roofline_pruned": [], "fallback": None, "rho": 0.0,
             "model_keys": 0, "rng": {}}
    with pytest.raises(ValueError, match="enumeration"):
        model_guided(run, space, make_policy("eager", tolerance=0.25),
                     start_state=stale)


# -- roofline prefilter -------------------------------------------------------

class _StubRun(BackendRun):
    """Deterministic driver-level backend: fixed lower bounds and trial
    times, no kernel structure (exercises the uniform fallback too)."""

    def __init__(self, bounds, times):
        self.bounds = bounds
        self.times = times
        self.trials = []

    def reset_models(self):
        pass

    def cost_lower_bound(self, point):
        return self.bounds.get(point.name)

    def run_trial(self, point):
        self.trials.append(point.name)
        t = self.times[point.name]
        return Measurement(predicted=t, time=t, cost=t, executed=1)


def _stub_space():
    return SearchSpace(name="stub", points=[
        ConfigPoint(name="fast"), ConfigPoint(name="slow"),
        ConfigPoint(name="unknown")])


def test_roofline_prefilter_never_dispatches_dominated_points():
    pol = make_policy("conditional", tolerance=0.25)
    run = _StubRun(bounds={"fast": 0.5, "slow": 2.0},
                   times={"fast": 0.6, "slow": 2.5, "unknown": 1.0})
    records, extra = model_guided(
        run, _stub_space(), pol, top_k=3, seed=0,
        incumbent={"mean": 1.0, "halfwidth": 0.1})
    assert extra["fallback"] == "uniform"      # no kernel structure
    # lower bound 2.0 > incumbent upper 1.1: provably dominated, never run
    assert extra["roofline_pruned"] == ["slow"]
    assert "slow" not in run.trials
    assert "slow" not in extra["dispatched"]
    # an unknown bound (None) is not a proof: the point stays dispatched
    assert set(extra["dispatched"]) == {"fast", "unknown"}
    slow = next(r for r in records if r.name == "slow")
    assert slow.predictions == [] and slow.extra["roofline_pruned"]
    assert extra["best"] == "fast"


def test_roofline_prefilter_empty_incumbent_passes_everything():
    pol = make_policy("conditional", tolerance=0.25)
    for incumbent in (None, {}):
        run = _StubRun(bounds={"fast": 0.5, "slow": 2.0},
                       times={"fast": 0.6, "slow": 2.5, "unknown": 1.0})
        _, extra = model_guided(run, _stub_space(), pol, top_k=3,
                                seed=0, incumbent=incumbent)
        assert extra["roofline_pruned"] == []
        assert set(extra["dispatched"]) == {"fast", "slow", "unknown"}


def test_roofline_prefilter_can_prune_everything():
    pol = make_policy("conditional", tolerance=0.25)
    run = _StubRun(bounds={"fast": 0.5, "slow": 2.0, "unknown": 3.0},
                   times={"fast": 0.6, "slow": 2.5, "unknown": 1.0})
    records, extra = model_guided(run, _stub_space(), pol, top_k=3,
                                  seed=0, incumbent=0.1)
    assert run.trials == [] and extra["best"] is None
    assert extra["dispatched"] == [] and extra["coverage"] == 0.0
    assert all(r.predictions == [] for r in records)


def test_roofline_prune_lands_in_the_task_journal():
    """Through the sweep scheduler: a dominated candidate is absent from
    the journaled study's dispatch list and its record shows no
    measurements — the 'never dispatched' proof survives in the
    checkpoint, not just the in-memory result."""
    space = _capital_space()
    ck = os.path.join(tempfile.mkdtemp(prefix="repro-search-"), "ck.json")
    opts = {"banks": [_bank()], "seed": 0,
            "incumbent": {"upper": 1e-12}}    # dominates every candidate
    (res,) = _session(space, search_options=opts).sweep(
        policies=["eager"], tolerances=[0.25], checkpoint=ck)
    with open(ck) as f:
        (journaled,) = json.load(f)["results"].values()
    assert journaled["extra"]["dispatched"] == []
    assert journaled["extra"]["roofline_pruned"] == \
        res.extra["roofline_pruned"] != []
    for rec in journaled["records"]:
        assert rec["predictions"] == [] and rec["executed"] == 0


# -- degenerate model: uniform fallback ---------------------------------------

def test_empty_model_falls_back_to_uniform_sampling():
    space = _capital_space().subset(5)
    result = _session(space, search_options={"banks": [], "seed": 3,
                                             "top_k": 2}).run()
    assert result.extra["fallback"] == "uniform"
    assert len(result.extra["dispatched"]) == 2
    again = _session(space, search_options={"banks": [], "seed": 3,
                                            "top_k": 2}).run()
    assert _strip(again) == _strip(result)         # seed-deterministic


# -- SearchSpace enumeration order --------------------------------------------

def test_space_rejects_duplicate_point_names():
    with pytest.raises(ValueError, match="twice"):
        SearchSpace(name="dup", points=[ConfigPoint(name="a"),
                                        ConfigPoint(name="a")])


def test_space_enumeration_order_is_construction_order():
    pts = [ConfigPoint(name=f"p{i}") for i in range(5)]
    space = SearchSpace(name="s", points=pts)
    assert [p.name for p in space] == [f"p{i}" for i in range(5)]
    assert [p.name for p in space.subset(3)] == ["p0", "p1", "p2"]


def test_order_fingerprint_pins_the_enumeration():
    space = _capital_space()
    # process-stable: pinned literal — a reordering of the study's points
    # (or a renamed config) must fail loudly, because checkpointed
    # model-guided selections index into this exact sequence
    assert space.order_fingerprint() == "order:8f95fc03:15"
    reordered = SearchSpace(name=space.name,
                            points=list(reversed(space.points)),
                            world_size=space.world_size)
    assert reordered.order_fingerprint() != space.order_fingerprint()
    assert space.subset(5).order_fingerprint() != space.order_fingerprint()
