"""Cross-study statistics transfer (repro.api.transfer) contract tests.

- neutrality: an empty or irrelevant prior is bit-identical to a fresh
  session (golden parity through the session front-end);
- transfer: a warm-started Capital study selects the same configuration
  as the cold study while executing strictly fewer kernel invocations;
- the bank round-trips losslessly through JSON (and disk);
- checkpoint/resume of a warm-started session is bit-identical to an
  uninterrupted warm run, and warm results are journaled under a
  different key than cold ones (no cross-replay);
- structural keys normalize communicator geometry by the world size;
- discounting widens CIs, and the Gaussian-copula-style remap adopts the
  target marginal for matched kernels while rescaling source-only ones.
"""

import json

import pytest

from repro.api import (AutotuneSession, ConfigPoint, SearchSpace,
                       SimBackend, StatisticsBank, WallClockBackend)
from repro.core.policies import POLICIES
from repro.core.signatures import comm_sig, comp_sig, p2p_sig, \
    structural_key
from repro.core.stats import KernelStats
from repro.core.tuner import space_of_study
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2

from golden_runner import GOLDEN_PATH, _studies

GOLDEN_FIELDS = ("full_time", "predicted", "rel_error", "comp_error",
                 "selective_cost", "full_cost", "executed", "skipped",
                 "predictions")


def _backend():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    return SimBackend(timer=cm.sample)


def _session(space, pol, **kw):
    return AutotuneSession(space, backend=_backend(), policy=pol,
                           tolerance=0.25, trials=2, **kw)


def _stats_of(xs) -> KernelStats:
    ks = KernelStats()
    for x in xs:
        ks.update(x)
    return ks


def _strip(result) -> dict:
    d = result.to_json()
    d.pop("wall_s", None)
    return d


# -- neutrality: empty/irrelevant priors --------------------------------------

def test_empty_and_irrelevant_priors_are_bit_identical():
    space = space_of_study(_studies()[1])          # golden-capital
    irrelevant = StatisticsBank(
        {"comp:nosuchkernel(7)": _stats_of([1.0, 1.1, 0.9, 1.0]),
         "comm:bcast(b8,s1,t1)": _stats_of([2.0, 2.1, 1.9, 2.0])})
    for pol in ("conditional", "eager"):
        fresh = _session(space, pol).run()
        empty = _session(space, pol, prior=StatisticsBank()).run()
        irrel = _session(space, pol, prior=irrelevant).run()
        assert _strip(empty) == _strip(fresh)
        assert _strip(irrel) == _strip(fresh)


def test_empty_prior_matches_golden_reports():
    """Golden parity through the warm-start plumbing: a session carrying a
    no-op prior still reproduces the seed engine's records bit-for-bit."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    study = _studies()[1]
    space = space_of_study(study)
    for pol in POLICIES:
        result = _session(space, pol, prior=StatisticsBank(),
                          collect_stats=True).run()
        g_recs = golden[study.name][pol]
        got = json.loads(json.dumps([r.to_json() for r in result.records]))
        for g, n in zip(g_recs, got):
            assert n["name"] == g["name"]
            for field in GOLDEN_FIELDS:
                assert n[field] == g[field], \
                    f"{pol}/{g['name']}/{field}: {n[field]!r} != {g[field]!r}"


# -- transfer on the Capital study --------------------------------------------

def test_warm_capital_same_winner_fewer_executions():
    space = space_of_study(_studies()[1])          # golden-capital, eager
    cold = _session(space, "eager", collect_stats=True).run()
    bank = cold.stats_bank()
    assert bank is not None and len(bank) > 0
    warm = _session(space, "eager", prior=bank).run()
    assert warm.chosen.name == cold.chosen.name
    cold_exec = sum(r.executed for r in cold.records)
    warm_exec = sum(r.executed for r in warm.records)
    assert warm_exec < cold_exec
    assert warm.selective_tuning_time < cold.selective_tuning_time
    # the prior, not luck: warm predictions stay within the tolerance
    assert all(r.rel_error <= 0.25 for r in warm.records)


def test_warm_resetting_study_reseeds_every_configuration():
    """golden-slate resets statistics between configurations; the prior
    must re-seed after each reset (the bank itself banks pre-reset
    statistics — kernels of both tile sizes), and the study overall
    executes less warm than cold.  Individual kernels can execute MORE
    warm — a byte-bucketed comm signature pools two configurations'
    message sizes, and that mixture prior's wider CI delays its skip —
    so the claim is study-level, not per-kernel."""
    space = space_of_study(_studies()[0])          # golden-slate, resets
    cold = _session(space, "online", collect_stats=True).run()
    bank = cold.stats_bank()
    assert "comp:potrf(64)" in bank.entries        # config 0's tile
    assert "comp:potrf(128)" in bank.entries       # config 1's, post-reset
    warm = _session(space, "online", prior=bank).run()
    assert sum(r.executed for r in warm.records) < \
        sum(r.executed for r in cold.records)
    assert all(r.rel_error <= 0.25 for r in warm.records)


def test_wallclock_warm_start_skips_from_trial_one():
    sig_a, sig_b = comp_sig("ka", 1), comp_sig("kb", 2)
    now = [0.0]
    durations = {sig_a: 1.0, sig_b: 0.01}

    def clock():
        return now[0]

    def make_thunk(sig):
        def thunk():
            now[0] += durations[sig]
        return thunk

    kernels = [(sig_a, make_thunk(sig_a), 1),
               (sig_b, make_thunk(sig_b), 1)]
    space = SearchSpace(name="fake", points=[
        ConfigPoint(name="c0", params={"i": 0}),
        ConfigPoint(name="c1", params={"i": 1})])

    def run(prior=None):
        return AutotuneSession(
            space, backend=WallClockBackend(lambda p: kernels, clock=clock),
            policy="eager", tolerance=1.0, min_samples=2, trials=4,
            collect_stats=True, prior=prior, prior_discount=1.0).run()

    cold = run()
    warm = run(prior=cold.stats_bank())
    assert cold.selective_tuning_time > 0
    assert warm.selective_tuning_time == 0.0      # everything pre-skipped
    assert warm.chosen.name == cold.chosen.name


# -- lossless serialization ---------------------------------------------------

def test_bank_json_roundtrip_lossless(tmp_path):
    bank = StatisticsBank(
        {"comp:gemm(64,64,64)": _stats_of([1.0, 1.25, 0.75, 1.125]),
         "comp:potrf(128)": _stats_of([3.0]),
         "comm:bcast(b4096,s1,t1)": _stats_of([0.5, 0.5000001]),
         "comm:send(b128,s1/4,t0)": _stats_of([2.0 ** -40, 1e-9])},
        meta=[{"study": "golden-capital", "policy": "eager",
               "tolerance": 0.25}])
    back = StatisticsBank.from_json(json.loads(json.dumps(bank.to_json())))
    assert back.meta == bank.meta
    assert set(back.entries) == set(bank.entries)
    for k, st in bank.entries.items():
        b = back.entries[k]
        assert (b.n, b.mean, b.m2, b.total, b.min_t, b.max_t) == \
            (st.n, st.mean, st.m2, st.total, st.min_t, st.max_t)
    assert back.fingerprint() == bank.fingerprint()
    # disk round-trip
    path = str(tmp_path / "bank.json")
    bank.save(path)
    assert StatisticsBank.load(path).fingerprint() == bank.fingerprint()


def test_harvested_bank_roundtrips_through_result_json():
    """The bank a session attaches to StudyResult.extra must survive the
    result's own JSON round-trip (what checkpoints and sweep pipes do)."""
    from repro.api import StudyResult
    space = space_of_study(_studies()[1])
    cold = _session(space, "eager", collect_stats=True).run()
    back = StudyResult.from_json(json.loads(json.dumps(cold.to_json())))
    b0, b1 = cold.stats_bank(), back.stats_bank()
    assert b1.fingerprint() == b0.fingerprint()


def test_bank_merge_equals_concatenated_streams():
    xs, ys = [1.0, 2.0, 3.0], [4.0, 5.0]
    a = StatisticsBank({"k": _stats_of(xs), "only-a": _stats_of([7.0])})
    b = StatisticsBank({"k": _stats_of(ys), "only-b": _stats_of([8.0])})
    m = a.merge(b)
    ref = _stats_of(xs + ys)
    got = m.entries["k"]
    assert got.n == ref.n
    assert got.mean == pytest.approx(ref.mean, rel=1e-12)
    assert got.m2 == pytest.approx(ref.m2, rel=1e-9)
    assert set(m.entries) == {"k", "only-a", "only-b"}
    # sources untouched
    assert a.entries["k"].n == len(xs)


# -- warm checkpoint/resume ---------------------------------------------------

class _FailingBackend(SimBackend):
    """Raises on the named configuration's reference run, once."""

    def __init__(self, fail_at: str, **kw):
        super().__init__(**kw)
        self.fail_at = fail_at
        self.tripped = False

    def open(self, *a, **kw):
        run = super().open(*a, **kw)
        orig = run.run_reference

        def ref(point):
            if not self.tripped and point.name == self.fail_at:
                self.tripped = True
                raise RuntimeError("interrupted")
            return orig(point)

        run.run_reference = ref
        return run


def test_warm_checkpoint_resume_bit_identical(tmp_path):
    space = space_of_study(_studies()[0])          # resets between configs
    bank = _session(space, "online", collect_stats=True).run().stats_bank()

    def session(backend):
        return AutotuneSession(space, backend=backend, policy="online",
                               tolerance=0.25, trials=2, prior=bank)

    reference = session(_backend()).run()
    ck = str(tmp_path / "warm.json")
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    failing = _FailingBackend(space.points[1].name, timer=cm.sample)
    with pytest.raises(RuntimeError, match="interrupted"):
        session(failing).run(checkpoint=ck)
    resumed = session(failing).run(checkpoint=ck)
    assert _strip(resumed) == _strip(reference)


def test_checkpoint_keys_separate_warm_from_cold(tmp_path):
    """A journaled cold result must not satisfy a warm session (and vice
    versa): the prior fingerprint is part of the study key."""
    space = space_of_study(_studies()[1])
    cold_session = _session(space, "eager", collect_stats=True)
    ck = str(tmp_path / "ck.json")
    cold = cold_session.run(checkpoint=ck)
    bank = cold.stats_bank()
    warm_session = _session(space, "eager", prior=bank)
    k_cold = cold_session._key(cold_session._policy(), 0, 0)
    k_warm = warm_session._key(warm_session._policy(), 0, 0)
    assert k_cold != k_warm
    # running warm against the cold checkpoint recomputes (fresh result,
    # fewer executions), rather than replaying the journaled cold study
    warm = warm_session.run(checkpoint=ck)
    assert sum(r.executed for r in warm.records) < \
        sum(r.executed for r in cold.records)
    # distinct banks get distinct fingerprints
    assert bank.discounted(0.5).fingerprint() != bank.fingerprint()


def test_resumed_study_exports_no_partial_bank(tmp_path):
    """Configurations replayed from a journal never fed the resumed run's
    models; presenting the remainder as the study's bank would silently
    drop their kernels — the resumed result must export no bank at all."""
    space = space_of_study(_studies()[0])          # resets between configs
    ck = str(tmp_path / "resume.json")
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, bias_sigma=0.0)
    failing = _FailingBackend(space.points[1].name, timer=cm.sample)

    def session(backend):
        return AutotuneSession(space, backend=backend, policy="online",
                               tolerance=0.25, trials=2,
                               collect_stats=True)

    with pytest.raises(RuntimeError, match="interrupted"):
        session(failing).run(checkpoint=ck)
    resumed = session(failing).run(checkpoint=ck)
    assert resumed.stats_bank() is None
    # an uninterrupted run of the same study does export one
    assert session(_backend()).run().stats_bank() is not None


def test_harvest_banks_prior_exactly_once_across_resets():
    """A warm run's kbar entries are merge(prior, new samples); harvesting
    at every model reset must bank only the measured deltas, folding the
    prior back in exactly once at export — chained warm-starts must not
    compound transferred confidence (what reviewers call C-fold prior
    inflation)."""
    from repro.api.transfer import Harvest
    sig = comp_sig("gemm", 8, 8, 8)
    prior_stats = _stats_of([1.0, 1.2, 0.8, 1.0, 1.1, 0.9])
    bank = StatisticsBank({structural_key(sig, 4): prior_stats})
    h = Harvest(4, bank)
    deltas = [[2.0, 2.2], [1.5], [3.0, 3.1, 2.9]]
    for d in deltas[:-1]:                          # two model resets
        table = prior_stats.copy()
        for x in d:
            table.update(x)
        h.add({sig: table})
    last = prior_stats.copy()
    for x in deltas[-1]:
        last.update(x)
    out = StatisticsBank.from_json(h.payload({sig: last}))
    got = out.entries[structural_key(sig, 4)]
    ref = _stats_of([x for d in deltas for x in d] +
                    [1.0, 1.2, 0.8, 1.0, 1.1, 0.9])
    assert got.n == ref.n                          # prior counted ONCE
    assert got.mean == pytest.approx(ref.mean, rel=1e-9)
    assert got.m2 == pytest.approx(ref.m2, rel=1e-6)
    # an unobserved prior kernel passes through unchanged
    h2 = Harvest(4, bank)
    out2 = StatisticsBank.from_json(h2.payload({}))
    assert out2.entries[structural_key(sig, 4)].n == prior_stats.n


def test_kernelstats_minus_inverts_merge():
    prior = _stats_of([1.0, 1.5, 0.5, 1.0])
    delta = _stats_of([4.0, 4.5, 3.5])
    total = prior.copy()
    total.merge(delta)
    back = total.minus(prior)
    assert back.n == delta.n
    assert back.mean == pytest.approx(delta.mean, rel=1e-12)
    assert back.m2 == pytest.approx(delta.m2, rel=1e-9)
    assert total.minus(total) is None


def test_checkpoint_key_format_is_legacy_stable():
    """Keys written by pre-transfer sessions must keep resolving: the
    canonical key string of a JSON-native study key is byte-identical to
    the historical ``json.dumps(key, sort_keys=True)`` form."""
    from repro.api.session import _Checkpoint
    key = {"space": "golden-slate", "n_points": 2,
           "backend": {"name": "sim", "overhead": 1e-06,
                       "machine": None, "timer": "custom",
                       "cost_model": "default"},
           "policy": "online", "tolerance": 0.25, "trials": 2,
           "search": "exhaustive", "seed": 0, "allocation": 0}
    assert _Checkpoint._k(key) == json.dumps(key, sort_keys=True)


# -- structural keys ----------------------------------------------------------

def test_structural_keys_normalize_world_geometry():
    # compute kernels: world-independent, compact str form
    g = comp_sig("gemm", 64, 64, 64)
    assert structural_key(g, 8) == structural_key(g, 4096) \
        == "comp:gemm(64,64,64)"
    # full-world collectives match across processor counts
    assert structural_key(comm_sig("bcast", 1000, 64, 1), 64) \
        == structural_key(comm_sig("bcast", 1000, 512, 1), 512)
    # same relative sub-grid matches; different fraction does not
    assert structural_key(comm_sig("allreduce", 512, 8, 1), 64) \
        == structural_key(comm_sig("allreduce", 512, 64, 1), 512)
    assert structural_key(comm_sig("allreduce", 512, 8, 1), 64) \
        != structural_key(comm_sig("allreduce", 512, 16, 1), 64)
    # contiguous (stride<=1) is kept verbatim; strided is world-relative:
    # a 1/8-world stride-1/8 fiber matches at any processor count
    assert structural_key(comm_sig("bcast", 64, 8, 8), 64) \
        == structural_key(comm_sig("bcast", 64, 32, 32), 256)
    assert structural_key(comm_sig("bcast", 64, 8, 8), 64) \
        != structural_key(comm_sig("bcast", 64, 16, 16), 256)
    # p2p: size-2 stride-0 signatures match across worlds
    assert structural_key(p2p_sig("send", 100), 16) \
        == structural_key(p2p_sig("send", 100), 1024)
    # byte bucketing flows through (p2p_sig buckets to powers of two)
    assert "b128" in structural_key(p2p_sig("send", 100), 16)


# -- per-key quality filters ---------------------------------------------------

def test_filtered_drops_high_dispersion_entries():
    tight = _stats_of([1.0, 1.02, 0.98, 1.0, 1.01, 0.99])
    mixture = _stats_of([1.0, 1.1, 0.9, 4.0, 4.1, 3.9])   # two modes pooled
    thin = _stats_of([2.0])                                # no variance yet
    bank = StatisticsBank({"tight": tight, "mixture": mixture,
                           "thin": thin})
    f = bank.filtered(max_cv=0.5)
    assert set(f.entries) == {"tight"}
    assert f.entries["tight"].n == tight.n
    # sources untouched, provenance recorded
    assert set(bank.entries) == {"tight", "mixture", "thin"}
    assert {"filter_max_cv": 0.5} in f.meta
    # threshold is inclusive on the cv itself
    assert "mixture" in bank.filtered(max_cv=10.0).entries


def test_prior_filter_on_resetting_study():
    """The ROADMAP regression (see the note on
    test_warm_resetting_study_reseeds_every_configuration): golden-slate's
    bank pools mixture distributions across the two tile configurations
    under one structural key, and that high-dispersion prior delays skips.
    Seeding through ``prior_max_cv`` drops exactly the dispersed entries,
    so the filtered warm study executes no more than the unfiltered one —
    strictly fewer here — while keeping the winner and the error bound."""
    space = space_of_study(_studies()[0])          # golden-slate, resets
    cold = _session(space, "online", collect_stats=True).run()
    bank = cold.stats_bank()
    cv = {k: st.std / st.mean for k, st in bank.entries.items()
          if st.n > 1 and st.mean > 0}
    assert max(cv.values()) > 0.5                  # the pooled mixture
    warm = _session(space, "online", prior=bank).run()
    filtered = _session(space, "online", prior=bank,
                        prior_max_cv=0.5).run()
    # the two golden-slate configs are near-ties (cold itself picks the
    # slightly-worse one, optimum_quality 0.93): the filter must keep the
    # warm study's pick and near-optimal selection quality
    assert filtered.chosen.name == warm.chosen.name
    assert filtered.optimum_quality > 0.99
    assert sum(r.executed for r in filtered.records) < \
        sum(r.executed for r in warm.records)
    assert sum(r.executed for r in filtered.records) < \
        sum(r.executed for r in cold.records)
    assert all(r.rel_error <= 0.25 for r in filtered.records)
    # the filter is part of the session's prior identity: journaled
    # filtered results never replay as unfiltered warm ones
    s_warm = _session(space, "online", prior=bank)
    s_filt = _session(space, "online", prior=bank, prior_max_cv=0.5)
    assert s_warm._key(s_warm._policy(), 0, 0) != \
        s_filt._key(s_filt._policy(), 0, 0)


# -- discounting and the copula remap ----------------------------------------

def test_discount_widens_ci_and_preserves_moments():
    st = _stats_of([1.0, 1.01, 0.99, 1.0, 1.02, 0.98, 1.0, 1.0])
    bank = StatisticsBank({"k": st})
    half = bank.discounted(0.5).entries["k"]
    assert half.n == st.n // 2
    assert half.mean == pytest.approx(st.mean)
    assert half.variance == pytest.approx(st.variance)
    assert half.ci_halfwidth() > st.ci_halfwidth()
    # discounting to below one sample drops the entry entirely
    assert len(StatisticsBank({"k": _stats_of([1.0])}).discounted(0.5)) == 0
    tight = _stats_of([0.9, 1.1] * 30)
    assert tight.is_predictable(0.05)
    assert not tight.discounted(0.1).is_predictable(0.05)


def test_copula_remap_adopts_target_marginal():
    src = StatisticsBank({
        "shared": _stats_of([1.0, 1.1, 0.9, 1.0, 1.05, 0.95] * 5),
        "src-only": _stats_of([4.0, 4.4, 3.6, 4.0]),
    })
    # target runs ~2x slower (e.g. a different allocation)
    tgt = StatisticsBank({
        "shared": _stats_of([2.0, 2.2, 1.8]),
        "tgt-only": _stats_of([9.0, 9.1]),
    })
    out = src.remapped(tgt, min_matches=1)
    shared = out.entries["shared"]
    # target marginal, pooled evidence
    assert shared.mean == pytest.approx(tgt.entries["shared"].mean)
    assert shared.n == src.entries["shared"].n + tgt.entries["shared"].n
    # source-only kernels ride the fitted global scale (~2x)
    scaled = out.entries["src-only"]
    ratio = scaled.mean / src.entries["src-only"].mean
    assert 1.5 < ratio < 2.7
    # relative spread is preserved under the through-origin scale
    assert scaled.std / scaled.mean == pytest.approx(
        src.entries["src-only"].std / src.entries["src-only"].mean)
    # target-only kernels pass through
    assert out.entries["tgt-only"].mean == \
        pytest.approx(tgt.entries["tgt-only"].mean)
    # a remapped bank is a valid, serializable prior
    rt = StatisticsBank.from_json(json.loads(json.dumps(out.to_json())))
    assert rt.fingerprint() == out.fingerprint()


def test_remap_identity_with_no_matches():
    src = StatisticsBank({"a": _stats_of([1.0, 1.1])})
    tgt = StatisticsBank({"b": _stats_of([5.0, 5.5])})
    out = src.remapped(tgt)
    assert out.entries["a"].mean == pytest.approx(1.05)
    assert out.entries["b"].mean == pytest.approx(5.25)


# -- CopulaModel: the Gaussian-copula candidate sampler -----------------------
#
# Property harness, PR-3 convention: shared property bodies driven by
# hypothesis where installed, plus seeded fallbacks that always run.

import numpy as np

from repro.api import CopulaModel

try:                                    # hypothesis is an optional test dep
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _bank_of(spec) -> StatisticsBank:
    """Build a bank from ``{key: [samples]}``."""
    return StatisticsBank({k: _stats_of(xs) for k, xs in spec.items()})


def _check_sample_seed_deterministic(banks):
    model = CopulaModel.fit(banks)
    a = model.sample(7, 123)
    b = model.sample(7, 123)
    np.testing.assert_array_equal(a, b)
    # an equivalent Generator yields the same stream as the int seed
    c = model.sample(7, np.random.default_rng(123))
    np.testing.assert_array_equal(a, c)
    assert a.shape == (7, len(model))
    assert (a >= 0.0).all()             # kernel times are nonnegative


def _check_copula_json_roundtrip(banks):
    model = CopulaModel.fit(banks)
    back = CopulaModel.from_json(json.loads(json.dumps(model.to_json())))
    assert back.keys == model.keys
    np.testing.assert_array_equal(back.mean, model.mean)
    np.testing.assert_array_equal(back.std, model.std)
    np.testing.assert_array_equal(back.n, model.n)
    assert back.rho == model.rho
    assert back.fingerprint() == model.fingerprint()
    np.testing.assert_array_equal(back.sample(5, 9), model.sample(5, 9))


def _check_quantile_monotone_and_marginal(banks):
    """The per-key quantile transform (the remap machinery's inverse CDF)
    is monotone non-decreasing in the level, and marginal-preserving:
    the median is exactly the fitted mean."""
    model = CopulaModel.fit(banks)
    qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99]
    for i, key in enumerate(model.keys):
        vals = [model.quantile(key, q) for q in qs]
        assert vals == sorted(vals), (key, vals)
        assert model.quantile(key, 0.5) == pytest.approx(
            float(model.mean[i]))


def _check_degenerate_banks_never_raise():
    # empty bank: falsy model, zero-width draws (callers fall back to
    # uniform candidate sampling — pinned in test_search.py)
    empty = CopulaModel.fit([StatisticsBank()])
    assert not empty and len(empty) == 0
    assert empty.sample(5, 0).shape == (5, 0)
    # no banks at all
    assert not CopulaModel.fit([])
    # single kernel
    one = CopulaModel.fit([_bank_of({"k": [1.0, 1.1, 0.9]})])
    assert len(one) == 1 and one.sample(4, 1).shape == (4, 1)
    # zero-variance stats: constant draws at the mean
    flat = CopulaModel.fit([_bank_of({"k": [2.0, 2.0, 2.0]})])
    np.testing.assert_array_equal(flat.sample(6, 2),
                                  np.full((6, 1), 2.0))
    # single-sample entries have no variance: std degrades to 0
    thin = CopulaModel.fit([_bank_of({"k": [3.0]})])
    np.testing.assert_array_equal(thin.sample(3, 3),
                                  np.full((3, 1), 3.0))


def _check_remap_monotone_and_marginal(src_spec, tgt_spec):
    src, tgt = _bank_of(src_spec), _bank_of(tgt_spec)
    out = src.remapped(tgt)
    # marginal-preserving: matched kernels adopt the TARGET marginal and
    # pool both banks' evidence
    for k in src.entries:
        if k in tgt.entries:
            assert out.entries[k].mean == pytest.approx(
                tgt.entries[k].mean)
            assert out.entries[k].n == src.entries[k].n + tgt.entries[k].n
    # monotone: the global log-space map never inverts the ordering of
    # source-only kernels (slope clamped >= 0)
    only = sorted((k for k in src.entries if k not in tgt.entries),
                  key=lambda k: src.entries[k].mean)
    outs = [out.entries[k].mean for k in only]
    assert all(a <= b + 1e-12 for a, b in zip(outs, outs[1:])), outs


def _random_bank_spec(rng, n_keys=None):
    n_keys = int(rng.integers(1, 9)) if n_keys is None else n_keys
    return {f"comp:k{i}({int(rng.integers(0, 3))})":
            [float(x) for x in
             rng.lognormal(rng.normal(0.0, 2.0), rng.uniform(0.05, 1.0),
                           size=int(rng.integers(1, 12)))]
            for i in range(n_keys)}


if HAVE_HYPOTHESIS:
    _samples = st.lists(st.floats(min_value=1e-6, max_value=1e6,
                                  allow_nan=False, allow_infinity=False),
                        min_size=1, max_size=12)
    _bank_specs = st.dictionaries(
        st.text(alphabet="abcdefgh", min_size=1, max_size=6), _samples,
        min_size=1, max_size=8)

    @given(st.lists(_bank_specs, min_size=1, max_size=3))
    @settings(max_examples=50, deadline=None)
    def test_copula_sample_seed_deterministic(specs):
        _check_sample_seed_deterministic([_bank_of(s) for s in specs])

    @given(st.lists(_bank_specs, min_size=1, max_size=3))
    @settings(max_examples=50, deadline=None)
    def test_copula_json_roundtrip(specs):
        _check_copula_json_roundtrip([_bank_of(s) for s in specs])

    @given(_bank_specs)
    @settings(max_examples=50, deadline=None)
    def test_copula_quantile_monotone_and_marginal(spec):
        _check_quantile_monotone_and_marginal([_bank_of(spec)])

    @given(_bank_specs, _bank_specs)
    @settings(max_examples=50, deadline=None)
    def test_remap_monotone_and_marginal_preserving(src, tgt):
        _check_remap_monotone_and_marginal(src, tgt)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_copula_sample_seed_deterministic():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_copula_json_roundtrip():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_copula_quantile_monotone_and_marginal():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_remap_monotone_and_marginal_preserving():
        pass


# -- seeded fallbacks: the same properties, always exercised ------------------

def test_copula_properties_seeded():
    rng = np.random.default_rng(17)
    for case in range(20):
        banks = [_bank_of(_random_bank_spec(rng))
                 for _ in range(int(rng.integers(1, 4)))]
        _check_sample_seed_deterministic(banks)
        _check_copula_json_roundtrip(banks)
        _check_quantile_monotone_and_marginal(banks)


def test_remap_properties_seeded():
    rng = np.random.default_rng(23)
    for case in range(20):
        _check_remap_monotone_and_marginal(
            _random_bank_spec(rng), _random_bank_spec(rng))


def test_copula_degenerate_banks():
    _check_degenerate_banks_never_raise()


def test_copula_marginal_means_recovered_by_sampling():
    """Law of large numbers over the sampler: per-key draw means approach
    the fitted marginal means (keys with modest spread, so the >= 0 clip
    is negligible)."""
    rng = np.random.default_rng(5)
    spec = {f"k{i}": [float(x) for x in
                      rng.normal(10.0 ** rng.integers(-3, 3), 0.0, 8) *
                      rng.uniform(0.9, 1.1, 8)]
            for i in range(6)}
    model = CopulaModel.fit([_bank_of(spec)])
    draws = model.sample(4000, 11)
    for i in range(len(model)):
        if model.std[i] <= 0.3 * model.mean[i]:
            assert draws[:, i].mean() == pytest.approx(
                float(model.mean[i]), rel=0.05)


def test_copula_correlation_from_multiple_banks():
    """Two banks that are scaled copies of each other (every kernel
    systematically fast/slow together) identify a strong shared factor;
    a single bank carries no dependence evidence (rho == 0)."""
    rng = np.random.default_rng(29)
    base = _random_bank_spec(rng, n_keys=8)
    fast = {k: [x * 0.25 for x in xs] for k, xs in base.items()}
    slow = {k: [x * 4.0 for x in xs] for k, xs in base.items()}
    multi = CopulaModel.fit([_bank_of(base), _bank_of(fast),
                             _bank_of(slow)])
    assert multi.rho > 0.5
    single = CopulaModel.fit([_bank_of(base)])
    assert single.rho == 0.0
    # correlated draws: with rho ~ 1 the cross-key draw correlation of
    # standardized columns is visibly positive
    d = multi.sample(2000, 7)
    cols = [i for i in range(len(multi)) if multi.std[i] > 0]
    z = (d[:, cols] - multi.mean[cols]) / multi.std[cols]
    corr = np.corrcoef(z.T)
    off = corr[~np.eye(len(cols), dtype=bool)]
    assert off.mean() > 0.3
