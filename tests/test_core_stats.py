"""core.stats: Welford estimator + CI machinery (property-based)."""

import math

import numpy as np
import pytest

try:                                    # hypothesis is an optional test dep:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # property tests skip, the rest run
    HAVE_HYPOTHESIS = False

from repro.core.stats import KernelStats, t_quantile_975

if HAVE_HYPOTHESIS:
    finite_floats = st.floats(min_value=1e-6, max_value=1e6,
                              allow_nan=False, allow_infinity=False)

    @given(st.lists(finite_floats, min_size=2, max_size=200))
    @settings(max_examples=100, deadline=None)
    def test_welford_matches_numpy(xs):
        ks = KernelStats()
        for x in xs:
            ks.update(x)
        assert ks.n == len(xs)
        np.testing.assert_allclose(ks.mean, np.mean(xs), rtol=1e-9)
        np.testing.assert_allclose(ks.variance, np.var(xs, ddof=1),
                                   rtol=1e-6, atol=1e-12)
        assert ks.min_t == min(xs) and ks.max_t == max(xs)

    @given(st.lists(finite_floats, min_size=2, max_size=60),
           st.lists(finite_floats, min_size=2, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_parallel_merge_equals_serial(xs, ys):
        a = KernelStats()
        for x in xs:
            a.update(x)
        b = KernelStats()
        for y in ys:
            b.update(y)
        a.merge(b)
        ref = KernelStats()
        for z in xs + ys:
            ref.update(z)
        np.testing.assert_allclose(a.mean, ref.mean, rtol=1e-9)
        np.testing.assert_allclose(a.variance, ref.variance, rtol=1e-6)
        assert a.n == ref.n

    @given(st.lists(finite_floats, min_size=3, max_size=50),
           st.integers(min_value=2, max_value=64))
    @settings(max_examples=60, deadline=None)
    def test_ci_shrinks_by_sqrt_freq(xs, freq):
        """The paper's sqrt(alpha) CI reduction from critical-path counts."""
        ks = KernelStats()
        for x in xs:
            ks.update(x)
        base = ks.ci_halfwidth(1)
        shrunk = ks.ci_halfwidth(freq)
        if math.isfinite(base) and base > 0:
            np.testing.assert_allclose(shrunk, base / math.sqrt(freq),
                                       rtol=1e-9)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_welford_matches_numpy():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_parallel_merge_equals_serial():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ci_shrinks_by_sqrt_freq():
        pass


def test_predictability_monotone_in_tolerance():
    ks = KernelStats()
    rng = np.random.default_rng(0)
    for x in rng.normal(1.0, 0.05, size=30):
        ks.update(max(x, 1e-3))
    tols = [0.001, 0.01, 0.1, 0.5, 1.0]
    flags = [ks.is_predictable(t) for t in tols]
    # once predictable at a tolerance, predictable at every larger one
    assert flags == sorted(flags)
    assert flags[-1]


def test_small_sample_widening():
    """2-3 samples must not be declared predictable at tight tolerance."""
    ks = KernelStats()
    ks.update(1.0)
    ks.update(1.0001)
    assert not ks.is_predictable(0.05, min_samples=3)
    assert t_quantile_975(1) > t_quantile_975(10) > t_quantile_975(1000)
