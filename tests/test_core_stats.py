"""core.stats: Welford estimator + CI machinery (property-based).

The hypothesis-driven properties are optional-dep-guarded; the cache-
coherence and merge-vs-concatenation properties additionally run against
deterministic seeded random streams so they are exercised even where
hypothesis is not installed (``scripts/check.sh`` fails the build if
hypothesis IS installed but the property suite skipped anyway).
"""

import math

import numpy as np
import pytest

try:                                    # hypothesis is an optional test dep:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # property tests skip, the rest run
    HAVE_HYPOTHESIS = False

from repro.core.stats import KernelStats, t_quantile_975


# -- shared property bodies (used by both hypothesis and seeded fallbacks) ----

def _check_cache_coherence(ops):
    """Replay an interleaved update/query stream against one live (cached)
    KernelStats and, at every query, a fresh uncached replay of the same
    samples.  The live object's memoized CI factor (``_hw``), its
    (n, tolerance)-keyed predictability verdicts, and its freq-monotone
    true/false thresholds must be indistinguishable from no caching."""
    live = KernelStats()
    seen = []
    for op in ops:
        if op[0] == "u":
            live.update(op[1])
            seen.append(op[1])
        else:
            _, tol, freq, ms = op
            fresh = KernelStats()
            for x in seen:
                fresh.update(x)
            assert live.ci_halfwidth(freq) == fresh.ci_halfwidth(freq), \
                (len(seen), freq)
            want = fresh.n >= ms and fresh.relative_ci(freq) <= tol
            got = live.is_predictable(tol, freq, ms)
            assert got == want, (len(seen), tol, freq, ms)


def _check_merge_equals_concat(chunks):
    """Chained Chan merges over any chunking of a sample stream produce
    the sufficient statistics of the concatenated stream."""
    merged = KernelStats()
    for chunk in chunks:
        part = KernelStats()
        for x in chunk:
            part.update(x)
        merged.merge(part)
    flat = [x for chunk in chunks for x in chunk]
    ref = KernelStats()
    for x in flat:
        ref.update(x)
    assert merged.n == ref.n
    assert merged.total == pytest.approx(ref.total, rel=1e-9)
    if ref.n:
        np.testing.assert_allclose(merged.mean, ref.mean, rtol=1e-9)
        assert merged.min_t == ref.min_t and merged.max_t == ref.max_t
    if ref.n >= 2:
        np.testing.assert_allclose(merged.m2, ref.m2, rtol=1e-6,
                                   atol=1e-15)


def _check_json_roundtrip(xs):
    ks = KernelStats()
    for x in xs:
        ks.update(x)
    back = KernelStats.from_json(ks.to_json())
    assert (back.n, back.mean, back.m2, back.total, back.min_t,
            back.max_t) == (ks.n, ks.mean, ks.m2, ks.total, ks.min_t,
                            ks.max_t)

if HAVE_HYPOTHESIS:
    finite_floats = st.floats(min_value=1e-6, max_value=1e6,
                              allow_nan=False, allow_infinity=False)

    @given(st.lists(finite_floats, min_size=2, max_size=200))
    @settings(max_examples=100, deadline=None)
    def test_welford_matches_numpy(xs):
        ks = KernelStats()
        for x in xs:
            ks.update(x)
        assert ks.n == len(xs)
        np.testing.assert_allclose(ks.mean, np.mean(xs), rtol=1e-9)
        np.testing.assert_allclose(ks.variance, np.var(xs, ddof=1),
                                   rtol=1e-6, atol=1e-12)
        assert ks.min_t == min(xs) and ks.max_t == max(xs)

    @given(st.lists(finite_floats, min_size=2, max_size=60),
           st.lists(finite_floats, min_size=2, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_parallel_merge_equals_serial(xs, ys):
        a = KernelStats()
        for x in xs:
            a.update(x)
        b = KernelStats()
        for y in ys:
            b.update(y)
        a.merge(b)
        ref = KernelStats()
        for z in xs + ys:
            ref.update(z)
        np.testing.assert_allclose(a.mean, ref.mean, rtol=1e-9)
        np.testing.assert_allclose(a.variance, ref.variance, rtol=1e-6)
        assert a.n == ref.n

    @given(st.lists(finite_floats, min_size=3, max_size=50),
           st.integers(min_value=2, max_value=64))
    @settings(max_examples=60, deadline=None)
    def test_ci_shrinks_by_sqrt_freq(xs, freq):
        """The paper's sqrt(alpha) CI reduction from critical-path counts."""
        ks = KernelStats()
        for x in xs:
            ks.update(x)
        base = ks.ci_halfwidth(1)
        shrunk = ks.ci_halfwidth(freq)
        if math.isfinite(base) and base > 0:
            np.testing.assert_allclose(shrunk, base / math.sqrt(freq),
                                       rtol=1e-9)

    _ops = st.one_of(
        st.tuples(st.just("u"), finite_floats),
        st.tuples(st.just("q"), st.sampled_from([0.01, 0.1, 0.25, 1.0]),
                  st.integers(min_value=1, max_value=64),
                  st.integers(min_value=2, max_value=5)))

    @given(st.lists(_ops, min_size=1, max_size=120))
    @settings(max_examples=80, deadline=None)
    def test_memoized_verdict_caches_match_uncached(ops):
        _check_cache_coherence(ops)

    @given(st.lists(st.lists(finite_floats, max_size=40), min_size=1,
                    max_size=6))
    @settings(max_examples=60, deadline=None)
    def test_chunked_merge_equals_concatenated_stream(chunks):
        _check_merge_equals_concat(chunks)

    @given(st.lists(finite_floats, max_size=50))
    @settings(max_examples=60, deadline=None)
    def test_sufficient_stats_json_roundtrip(xs):
        _check_json_roundtrip(xs)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_welford_matches_numpy():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_parallel_merge_equals_serial():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ci_shrinks_by_sqrt_freq():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_memoized_verdict_caches_match_uncached():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_chunked_merge_equals_concatenated_stream():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(seeded fallback below still runs)")
    def test_sufficient_stats_json_roundtrip():
        pass


# -- seeded fallbacks: the same properties, always exercised ------------------

def test_cache_coherence_seeded_streams():
    rng = np.random.default_rng(7)
    tols = [0.01, 0.1, 0.25, 1.0]
    for case in range(25):
        ops = []
        scale = 10.0 ** rng.integers(-6, 4)
        spread = float(rng.uniform(0.01, 1.0))
        for _ in range(int(rng.integers(3, 80))):
            if rng.random() < 0.6:
                ops.append(("u", float(
                    scale * max(rng.normal(1.0, spread), 1e-9))))
            else:
                ops.append(("q", tols[int(rng.integers(len(tols)))],
                            int(rng.integers(1, 64)),
                            int(rng.integers(2, 5))))
        _check_cache_coherence(ops)


def test_merge_equals_concat_seeded_streams():
    rng = np.random.default_rng(11)
    for case in range(25):
        chunks = [[float(x) for x in
                   rng.lognormal(0.0, 1.5, size=rng.integers(0, 30))]
                  for _ in range(int(rng.integers(1, 6)))]
        _check_merge_equals_concat(chunks)
        for chunk in chunks:
            _check_json_roundtrip(chunk)


def test_predictability_monotone_in_tolerance():
    ks = KernelStats()
    rng = np.random.default_rng(0)
    for x in rng.normal(1.0, 0.05, size=30):
        ks.update(max(x, 1e-3))
    tols = [0.001, 0.01, 0.1, 0.5, 1.0]
    flags = [ks.is_predictable(t) for t in tols]
    # once predictable at a tolerance, predictable at every larger one
    assert flags == sorted(flags)
    assert flags[-1]


def test_small_sample_widening():
    """2-3 samples must not be declared predictable at tight tolerance."""
    ks = KernelStats()
    ks.update(1.0)
    ks.update(1.0001)
    assert not ks.is_predictable(0.05, min_samples=3)
    assert t_quantile_975(1) > t_quantile_975(10) > t_quantile_975(1000)
