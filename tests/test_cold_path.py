"""Batched cold-run fast path: bit-identity against the scalar engine.

The PR-4 cold path splits the first (recording/forced) execution into a
structural recording pass plus a batched interpreter that pre-draws kernel
samples (vectorized when the cost model's straggler branch is off, scalar
fallback when it is on) and charges fused computation runs in bulk.  These
tests pin it to the scalar reference — ``trace_cache=False`` runs the
seed-style interleaved pass — requiring bit-identical:

- iteration reports (every ``IterationReport`` field),
- engine state after every iteration (statistics, mean mirrors, counts,
  path profiles), and
- the sampler RNG stream (bit-generator state after the run),

across all five policies, the three op-mix-distinct studies, straggler
branch on AND off, forced first runs, selective runs, and forced replays
(including ``update_stats=False`` reference runs).  Also pins the
optimized SLATE generator's op stream to a reference implementation and
the event-program identity of batched vs unbatched cold runs.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.critter import Critter
from repro.core.policies import POLICIES, policy
from repro.core.stats import KernelStats
from repro.linalg import candmc_qr, capital_cholesky, slate_cholesky
from repro.simmpi import Comp, Isend, Recv
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.runtime import Runtime

REPORT_FIELDS = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                 "measured_time", "max_measured_comp", "executed",
                 "skipped", "events")

STUDIES = {
    "slate": (16, lambda w: slate_cholesky.make_program(
        w, n=512, tile=64, lookahead=1, pr=4, pc=4)),
    "capital": (8, lambda w: capital_cholesky.make_program(
        w, n=256, block=32, strategy=1, grid_c=2)),
    "candmc": (16, lambda w: candmc_qr.make_program(
        w, m=1024, n=128, block=16, pr=4, pc=4)),
}


def _state_snapshot(critter):
    S = critter.state
    return (S.mean_arr.tobytes(), S.freq.tobytes(), S.seen.tobytes(),
            S.skip_ok.tobytes(), S.iter_exec.tobytes(), S.clock.tobytes(),
            S.path_exec.tobytes(), S.path_comm.tobytes(),
            S.goff.tobytes(), S.gmean.tobytes(),
            sorted(critter.global_off),
            sorted((r, sid, st.n, st.mean, st.m2, st.total, st.min_t,
                    st.max_t)
                   for r in range(S.n_ranks)
                   for sid, st in S.kbar[r].items()))


def _run_protocol(study, pol, straggler_p, trace_cache):
    """The tuner's per-configuration pattern: forced reference run, three
    selective trials, then a forced ``update_stats=False`` replay (the
    next configuration's reference measurement)."""
    world_size, make = STUDIES[study]
    w = World(world_size)
    c = Critter(w, policy(pol, tolerance=0.25))
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                   straggler_p=straggler_p)
    rt = Runtime(w, c, cm.sample, seed=3, trace_cache=trace_cache)
    prog = make(w)
    trace = []
    for i in range(4):
        res = rt.run(prog, force_execute=(i == 0))
        trace.append(tuple(getattr(res, f) for f in REPORT_FIELDS))
        trace.append(_state_snapshot(c))
    res = rt.run(prog, force_execute=True, update_stats=False)
    trace.append(tuple(getattr(res, f) for f in REPORT_FIELDS))
    trace.append(_state_snapshot(c))
    trace.append(rt._rng.bit_generator.state)
    return trace


@pytest.mark.parametrize("study", sorted(STUDIES))
@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("straggler_p", [0.002, 0.0],
                         ids=["straggler-on", "straggler-off"])
def test_cold_path_bit_identical(study, pol, straggler_p):
    scalar = _run_protocol(study, pol, straggler_p, trace_cache=False)
    batched = _run_protocol(study, pol, straggler_p, trace_cache=True)
    for i, (a, b) in enumerate(zip(scalar, batched)):
        assert a == b, (f"{study}/{pol}/straggler={straggler_p}: "
                        f"divergence at trace step {i}")


def test_rng_stream_batched_vs_scalar():
    """The RNG-order-compat contract in isolation: after a forced run the
    bit-generator state matches the scalar path exactly, for both the
    vectorized pre-draw (straggler off) and the scalar fallback."""
    for straggler_p in (0.0, 0.002):
        states = []
        for trace_cache in (False, True):
            w = World(16)
            c = Critter(w, policy("online", tolerance=0.25))
            cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                           straggler_p=straggler_p)
            rt = Runtime(w, c, cm.sample, seed=11,
                         trace_cache=trace_cache)
            rt.run(STUDIES["slate"][1](w), force_execute=True)
            states.append(rt._rng.bit_generator.state)
        assert states[0] == states[1], f"straggler_p={straggler_p}"


def test_bench_engine_verify_cold_path():
    """The bench_engine assertion wired into check.sh: batched and
    unbatched cold runs record identical event programs and produce
    bit-identical reports/RNG streams."""
    from benchmarks.bench_engine import verify_cold_path
    summary = verify_cold_path(16)
    assert summary["report"]["skipped"] == 0   # forced run executes all


def test_custom_timer_falls_back_to_scalar_draws():
    """A plain callable timer (no batch_info) must still produce
    bit-identical forced runs — the cold interpreter draws through the
    timer per event, in event order."""
    calls = []

    def timer(sig, rng):
        calls.append(sig.kind)
        return 0.5 + 0.25 * rng.random()

    def run(trace_cache):
        calls.clear()
        w = World(8)
        c = Critter(w, policy("conditional", tolerance=0.25))
        rt = Runtime(w, c, timer, seed=5, trace_cache=trace_cache)
        res = rt.run(STUDIES["capital"][1](w), force_execute=True)
        return ([getattr(res, f) for f in REPORT_FIELDS], list(calls),
                rt._rng.bit_generator.state)

    assert run(False) == run(True)


def test_update_many_matches_sequential_updates():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 0.4, 257).tolist()
    a, b = KernelStats(), KernelStats()
    for x in xs:
        a.update(x)
    b.update_many(xs[:100])
    b.update_many(xs[100:])
    assert (a.n, a.mean, a.m2, a.total, a.min_t, a.max_t) == \
        (b.n, b.mean, b.m2, b.total, b.min_t, b.max_t)


def test_batch_info_contract():
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0, straggler_p=0.0)
    w = World(4)
    prog = STUDIES["slate"][1]
    # straggler on -> no batching
    assert CostModel(KNL_STAMPEDE2, straggler_p=0.002).batch_info(
        [None]) is None
    assert cm.batch_info([]) is None
    from repro.core.signatures import comp_sig, p2p_sig
    sigs = [comp_sig("gemm", 64, 64, 64), p2p_sig("send", 4096),
            comp_sig("gemm", 64, 64, 64)]
    det, sigma = cm.batch_info(sigs)
    assert det.shape == sigma.shape == (3,)
    assert sigma[0] == cm.noise and sigma[1] == cm.comm_noise
    assert det[0] == det[2]
    # the batched draw reproduces scalar sample() exactly
    r1 = np.random.default_rng(7)
    r2 = np.random.default_rng(7)
    batched = det * np.exp(sigma * r1.standard_normal(3))
    scalar = [cm.sample(s, r2) for s in sigs]
    assert batched.tolist() == scalar
    assert r1.bit_generator.state == r2.bit_generator.state


# ---------------------------------------------------- SLATE stream pinning

def _reference_slate(world, *, n, tile, lookahead, pr, pc):
    """The pre-PR-4 scan-and-filter SLATE generator (owner() over every
    tile), kept verbatim as the reference the optimized
    arithmetic-progression form is pinned against."""
    assert pr * pc == world.size
    nt = n // tile
    tb = 8 * tile * tile

    def owner(i, j):
        return (i % pr) + pr * (j % pc)

    def program(rank, world):
        TAG_LKK, TAG_ROW, TAG_COL = 0, 1, 2

        def panel(k):
            if owner(k, k) == rank:
                yield Comp("potrf", (tile,))
                sent = set()
                for i in range(k + 1, nt):
                    o = owner(i, k)
                    if o != rank and o not in sent:
                        sent.add(o)
                        yield Isend(o, tb, (TAG_LKK, k))
            my_tiles = [i for i in range(k + 1, nt)
                        if owner(i, k) == rank]
            if my_tiles and owner(k, k) != rank:
                yield Recv(owner(k, k), tb, (TAG_LKK, k))
            for i in my_tiles:
                yield Comp("trsm", (tile, tile))
                sent = set()
                for j in range(k + 1, i + 1):
                    o = owner(i, j)
                    if o != rank and o not in sent:
                        sent.add(o)
                        yield Isend(o, tb, (TAG_ROW, k, i))
                sent = set()
                for i2 in range(i, nt):
                    o = owner(i2, i)
                    if o != rank and o not in sent:
                        sent.add(o)
                        yield Isend(o, tb, (TAG_COL, k, i))

        def recv_for_update(k, i, j, got):
            src_row = owner(i, k)
            if ("r", i) not in got:
                got.add(("r", i))
                if src_row != rank:
                    yield Recv(src_row, tb, (TAG_ROW, k, i))
            src_col = owner(j, k)
            if ("c", j) not in got:
                got.add(("c", j))
                if src_col != rank:
                    yield Recv(src_col, tb, (TAG_COL, k, j))

        def updates(k, js, got):
            for j in js:
                for i in range(j, nt):
                    if owner(i, j) != rank:
                        continue
                    yield from recv_for_update(k, i, j, got)
                    if i == j:
                        yield Comp("syrk", (tile, tile))
                    else:
                        yield Comp("gemm", (tile, tile, tile))

        deferred = []
        for k in range(nt):
            while deferred and deferred[0][0] < k - lookahead:
                dk, djs, dgot = deferred.pop(0)
                yield from updates(dk, djs, dgot)
            yield from panel(k)
            got = set()
            if lookahead > 0:
                near = [j for j in
                        range(k + 1, min(k + 1 + lookahead, nt))]
                far = [j for j in range(k + 1 + lookahead, nt)]
                yield from updates(k, near, got)
                if far:
                    deferred.append((k, far, got))
            else:
                yield from updates(k, list(range(k + 1, nt)), got)
        for dk, djs, dgot in deferred:
            yield from updates(dk, djs, dgot)

    return program


def _op_key(op):
    c = op.__class__.__name__
    if c == "Comp":
        return (c, op.name, op.params)
    if c in ("Isend", "Send"):
        return (c, op.dst, op.nbytes, op.tag)
    if c == "Recv":
        return (c, op.src, op.nbytes, op.tag)
    return (c,)


def _drain(progf, rank, w):
    g = progf(rank, w)
    out = []
    v = None
    try:
        while True:
            op = g.send(v)
            v = 1 if isinstance(op, Isend) else None
            out.append(_op_key(op))
    except StopIteration:
        return out


@pytest.mark.parametrize("geom", [
    (512, 64, 1, 4, 4), (512, 128, 0, 4, 4), (1024, 64, 2, 2, 8),
    (768, 128, 3, 8, 2), (512, 256, 1, 1, 16), (512, 256, 0, 16, 1),
])
def test_slate_program_stream_unchanged(geom):
    n, tile, la, pr, pc = geom
    w = World(pr * pc)
    fast = slate_cholesky.make_program(w, n=n, tile=tile, lookahead=la,
                                      pr=pr, pc=pc)
    ref = _reference_slate(w, n=n, tile=tile, lookahead=la, pr=pr, pc=pc)
    for r in range(pr * pc):
        assert _drain(fast, r, w) == _drain(ref, r, w), f"rank {r}"
